"""Parallelism substrate: sharding rules, logical axes, collective helpers."""
from repro.par.sharding import (
    LOGICAL_AXES, ShardingRules, logical_to_physical, spec_for,
    param_specs, named_shardings, data_spec, replicated,
)

__all__ = ["LOGICAL_AXES", "ShardingRules", "logical_to_physical", "spec_for",
           "param_specs", "named_shardings", "data_spec", "replicated"]
