"""Parallelism substrate: sharding rules, logical axes, collective helpers,
and the version-portable JAX compat shim (``repro.par.compat``)."""
from repro.par import compat
from repro.par.compat import abstract_mesh, axis_size, mark_varying, shard_map
from repro.par.sharding import (
    LOGICAL_AXES, ShardingRules, logical_to_physical, spec_for,
    param_specs, named_shardings, data_spec, replicated,
)

__all__ = ["LOGICAL_AXES", "ShardingRules", "logical_to_physical", "spec_for",
           "param_specs", "named_shardings", "data_spec", "replicated",
           "compat", "shard_map", "mark_varying", "abstract_mesh", "axis_size"]
