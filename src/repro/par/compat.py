"""Version-portable JAX compatibility layer for the distributed code paths.

The sharded hot paths (Gram psum, sharded top-k merge, compressed-gradient
all-reduce) are written against the *current* JAX surface — ``jax.shard_map``
with ``check_vma``, ``jax.lax.pcast`` varying-marks, the two-argument
``AbstractMesh(axis_sizes, axis_names)``. Those APIs moved or do not exist
on older releases (the pinned toolchain ships 0.4.x, where ``shard_map``
still lives under ``jax.experimental`` with a ``check_rep`` kwarg). This
module is the single seam every call site goes through:

  * ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
      Resolves ``jax.shard_map`` vs ``jax.experimental.shard_map.shard_map``
      and translates ``check_vma`` to whichever replication/varying-check
      kwarg the installed version understands (dropping it if neither does).
  * ``mark_varying(tree, axes)``
      ``jax.lax.pcast(..., to="varying")`` over a pytree where pcast exists;
      the identity elsewhere (pre-VMA shard_map needs no marking).
  * ``abstract_mesh(shape, names)``
      Builds ``jax.sharding.AbstractMesh`` through either constructor
      signature: new ``(axis_sizes, axis_names)`` or old
      ``(((name, size), ...),)`` pairs.
  * ``axis_size(axis)``
      ``jax.lax.axis_size`` where present, else ``psum(1, axis)`` (which
      constant-folds to the mesh axis size under tracing).
  * ``shard_map_eqn_body(eqn)`` / ``shard_map_eqn_device_count(eqn)``
      Jaxpr-introspection helpers for cost accounting: the sub-jaxpr and
      global device multiplier of a ``shard_map`` equation, tolerant of the
      param-layout differences between versions.
"""
from __future__ import annotations

import inspect
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import AbstractMesh

__all__ = [
    "JAX_VERSION", "HAS_NATIVE_SHARD_MAP", "HAS_PCAST",
    "shard_map", "mark_varying", "abstract_mesh", "axis_size", "axis_index",
    "shard_map_eqn_body", "shard_map_eqn_device_count",
]

JAX_VERSION: tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())

HAS_NATIVE_SHARD_MAP: bool = hasattr(jax, "shard_map")

if HAS_NATIVE_SHARD_MAP:
    _shard_map_impl = jax.shard_map
else:  # 0.4.x: still experimental
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_KWARGS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """``jax.shard_map`` across JAX versions.

    ``check_vma`` follows the newest spelling; it is forwarded as
    ``check_vma`` or ``check_rep`` depending on what the installed
    ``shard_map`` accepts, and silently dropped if it accepts neither.
    """
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_KWARGS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_KWARGS:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


HAS_PCAST: bool = hasattr(jax.lax, "pcast")


def mark_varying(tree: Any, axes: Sequence[str] | None) -> Any:
    """Mark every leaf varying over ``axes`` (VMA typing), where supported.

    On JAX versions with varying-manual-axes tracking, a scan carry created
    inside ``shard_map`` must be ``pcast`` to varying before collectives see
    it. Pre-VMA versions have no such distinction — identity there.
    """
    if not HAS_PCAST or not axes:
        return tree
    return jax.tree.map(
        lambda x: jax.lax.pcast(x, tuple(axes), to="varying"), tree)


_AM_OLD_SIGNATURE = "shape_tuple" in inspect.signature(
    AbstractMesh.__init__).parameters


def abstract_mesh(shape: Sequence[int], names: Sequence[str]) -> AbstractMesh:
    """Device-less mesh from parallel ``shape`` / ``names`` sequences.

    Newer JAX takes ``AbstractMesh(axis_sizes, axis_names)``; 0.4.x takes a
    single tuple of ``(name, size)`` pairs. Both yield a mesh whose
    ``.shape`` / ``.axis_names`` drive the sharding-rule engine without
    touching device state.
    """
    shape, names = tuple(shape), tuple(names)
    if len(shape) != len(names):
        raise ValueError(f"shape {shape} and names {names} length mismatch")
    if _AM_OLD_SIGNATURE:
        return AbstractMesh(tuple(zip(names, shape)))
    return AbstractMesh(shape, names)


if hasattr(jax.lax, "axis_size"):
    def axis_size(axis) -> int:
        """Size of a named mesh axis (or product over a tuple of axes)."""
        return jax.lax.axis_size(axis)
else:
    def axis_size(axis) -> int:
        """Size of a named mesh axis (or product over a tuple of axes).

        ``psum`` of a non-tracer constant folds to ``value * axis_size`` at
        trace time, so this is free inside jit/shard_map.
        """
        return jax.lax.psum(1, axis)


def axis_index(axis) -> jax.Array:
    """Flat linear index over one or more named mesh axes (row-major).

    Newer JAX accepts a tuple of axis names directly; older releases only
    take a single name, so the flat index is folded manually as
    ``idx = idx * size(ax) + axis_index(ax)`` — identical row-major order.
    """
    if isinstance(axis, str):
        return jax.lax.axis_index(axis)
    names = tuple(axis)
    if len(names) == 1:
        return jax.lax.axis_index(names[0])
    try:
        return jax.lax.axis_index(names)
    except (TypeError, ValueError):
        idx = jax.lax.axis_index(names[0])
        for name in names[1:]:
            idx = idx * axis_size(name) + jax.lax.axis_index(name)
        return idx


# ---------------------------------------------------------------------------
# Jaxpr introspection (cost accounting)
# ---------------------------------------------------------------------------


def shard_map_eqn_body(eqn) -> Any | None:
    """The (open) body jaxpr of a ``shard_map`` equation, or None."""
    cj = eqn.params.get("jaxpr")
    if cj is None:
        return None
    return cj.jaxpr if hasattr(cj, "jaxpr") else cj


def shard_map_eqn_device_count(eqn) -> float:
    """Global device multiplier of a ``shard_map`` equation.

    Body shapes are per-shard; costs scale back to global by the mesh
    device count. Falls back to 1.0 when the mesh param is unreadable.
    """
    mesh = eqn.params.get("mesh")
    for extract in (lambda m: np.prod(list(m.shape.values())),
                    lambda m: np.prod(m.axis_sizes),
                    lambda m: m.size):
        try:
            return float(extract(mesh))
        except Exception:
            continue
    return 1.0
