"""Encoder-processor-decoder message-passing GNN (GraphCast-style).

JAX has no sparse message-passing primitive beyond BCOO, so the edge
scatter/gather *is* part of the system: messages are gathered per edge
(``jnp.take`` on the node table), transformed by an edge MLP, and
aggregated with ``jax.ops.segment_sum`` (the paper-assigned aggregator).

Graph batching: batched small graphs (molecule shape) are expressed as one
block-diagonal graph via offset edge indices; sampled minibatch training
(minibatch_lg) consumes padded subgraphs from ``repro.data.graph``'s CSR
fanout sampler.

Distribution: edge arrays shard over the mesh's data axes; node tables
replicate (small) or shard over 'model' (ogb_products) with SPMD inserting
the gather/scatter collectives. The processor runs L layers via lax.scan.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import apply_layernorm, apply_mlp_stack, init_layernorm, init_mlp_stack


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "gnn"
    n_layers: int = 16
    d_hidden: int = 512
    d_in: int = 227            # node input features (n_vars for graphcast)
    d_edge_in: int = 4         # edge input features (e.g. displacement+len)
    d_out: int = 227
    aggregator: str = "sum"
    mesh_refinement: int = 6   # recorded for provenance (graphcast config)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True

    def param_count(self) -> int:
        h = self.d_hidden
        enc = self.d_in * h + h * h + self.d_edge_in * h + h * h
        proc = self.n_layers * ((3 * h) * h + h * h   # edge MLP [src,dst,e]->h
                                + (2 * h) * h + h * h)  # node MLP [h,agg]->h
        dec = h * h + h * self.d_out
        return enc + proc + dec


def init_gnn(key, cfg: GNNConfig) -> dict:
    ken, kee, kl, kd = jax.random.split(key, 4)
    h = cfg.d_hidden
    pdt = jnp.dtype(cfg.param_dtype)

    def init_proc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge_mlp": init_mlp_stack(k1, (3 * h, h, h), dtype=pdt),
            "node_mlp": init_mlp_stack(k2, (2 * h, h, h), dtype=pdt),
            "edge_norm": init_layernorm(h, pdt),
            "node_norm": init_layernorm(h, pdt),
        }

    layer_keys = jax.random.split(kl, cfg.n_layers)
    return {
        "node_encoder": init_mlp_stack(ken, (cfg.d_in, h, h), dtype=pdt),
        "edge_encoder": init_mlp_stack(kee, (cfg.d_edge_in, h, h), dtype=pdt),
        "layers": jax.vmap(init_proc_layer)(layer_keys),
        "decoder": init_mlp_stack(kd, (h, h, cfg.d_out), dtype=pdt),
    }


def forward(params: dict, nodes: jax.Array, edges: jax.Array,
            edge_index: jax.Array, cfg: GNNConfig,
            edge_mask: jax.Array | None = None) -> jax.Array:
    """nodes: (N, d_in); edges: (E, d_edge_in); edge_index: (2, E) [src; dst].

    ``edge_mask`` (E,) zeroes messages from padding edges (shard-even
    padding at scale). Returns per-node outputs (N, d_out).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    n_nodes = nodes.shape[0]
    src, dst = edge_index[0], edge_index[1]

    x = apply_mlp_stack(params["node_encoder"], nodes.astype(cdt),
                        act="silu", compute_dtype=cdt)
    e = apply_mlp_stack(params["edge_encoder"], edges.astype(cdt),
                        act="silu", compute_dtype=cdt)

    def body(carry, lp):
        x, e = carry
        xs = jnp.take(x, src, axis=0)
        xd = jnp.take(x, dst, axis=0)
        msg_in = jnp.concatenate([xs, xd, e], axis=-1)
        m = apply_mlp_stack(lp["edge_mlp"], msg_in, act="silu", compute_dtype=cdt)
        if edge_mask is not None:
            m = m * edge_mask.astype(cdt)[:, None]
        e_new = apply_layernorm(lp["edge_norm"], e + m)
        if cfg.aggregator == "sum":
            agg = jax.ops.segment_sum(m, dst, num_segments=n_nodes)
        elif cfg.aggregator == "mean":
            s = jax.ops.segment_sum(m, dst, num_segments=n_nodes)
            c = jax.ops.segment_sum(jnp.ones((m.shape[0], 1), cdt), dst,
                                    num_segments=n_nodes)
            agg = s / jnp.maximum(c, 1.0)
        else:  # max — isolated nodes get -inf from segment_max: clamp to 0
            agg = jax.ops.segment_max(m, dst, num_segments=n_nodes)
            agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
        upd_in = jnp.concatenate([x, agg], axis=-1)
        u = apply_mlp_stack(lp["node_mlp"], upd_in, act="silu", compute_dtype=cdt)
        x_new = apply_layernorm(lp["node_norm"], x + u)
        return (x_new, e_new), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, e), _ = jax.lax.scan(body_fn, (x, e), params["layers"])
    return apply_mlp_stack(params["decoder"], x, act="silu", compute_dtype=cdt)


def mse_loss(params: dict, batch: dict, cfg: GNNConfig) -> jax.Array:
    """Node-regression loss with an optional per-node weight/validity mask."""
    out = forward(params, batch["nodes"], batch["edges"],
                  batch["edge_index"], cfg, edge_mask=batch.get("edge_mask"))
    err = (out - batch["targets"].astype(out.dtype)) ** 2
    w = batch.get("node_mask")
    if w is not None:
        w = w.astype(out.dtype)[:, None]
        return (err * w).sum() / jnp.maximum(w.sum() * cfg.d_out, 1.0)
    return err.mean()
