"""Bi-encoder for dense retrieval — the paper's embedding model family.

A bidirectional transformer encoder (BERT-style: ANCE/TAS-B/Contriever are
all 6–12-layer encoders) with mean or CLS pooling, producing d-dim text
embeddings, trained with in-batch-negative contrastive loss (InfoNCE).

At production batch sizes the (B, B) in-batch logit matrix is sharded:
``contrastive_loss_sharded`` computes the local block of logits per device
and reduces the log-partition with a psum — batch 65k trains without a
65k×65k replicated logit matrix.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import layers as L
from repro.models.transformer import TransformerConfig, _init_layer, _norm
from repro.par import compat


@dataclasses.dataclass(frozen=True)
class BiEncoderConfig:
    name: str = "biencoder"
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    d_ff: int = 3072
    vocab: int = 30522
    embed_dim: int = 768          # output embedding dim (d in the paper)
    max_len: int = 512
    pooling: str = "mean"         # mean (contriever) | cls (tas-b)
    temperature: float = 0.05
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True

    def lm_cfg(self) -> TransformerConfig:
        return TransformerConfig(
            name=self.name, n_layers=self.n_layers, d_model=self.d_model,
            n_heads=self.n_heads, n_kv_heads=self.n_heads, d_ff=self.d_ff,
            vocab=self.vocab, norm="layernorm", act="gelu",
            param_dtype=self.param_dtype, compute_dtype=self.compute_dtype,
            remat=self.remat)

    def param_count(self) -> int:
        lm = self.lm_cfg()
        d = lm.d_model
        per_layer = 4 * d * d + 3 * d * lm.d_ff + 2 * d
        return (lm.n_layers * per_layer + lm.vocab * d
                + self.max_len * d + d * self.embed_dim)


def init_biencoder(key, cfg: BiEncoderConfig) -> dict:
    lm = cfg.lm_cfg()
    ke, kp, kl, kh = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, lm.n_layers)
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02).astype(lm.pdt),
        "pos_embed": (jax.random.normal(kp, (cfg.max_len, cfg.d_model)) * 0.02).astype(lm.pdt),
        "layers": jax.vmap(lambda k: _init_layer(k, lm))(layer_keys),
        "final_norm": L.init_layernorm(cfg.d_model, lm.pdt),
        "proj": L.init_dense(kh, cfg.d_model, cfg.embed_dim, dtype=lm.pdt),
    }


def encode(params: dict, tokens: jax.Array, mask: jax.Array,
           cfg: BiEncoderConfig) -> jax.Array:
    """tokens, mask: (B, S) -> L2-normalised embeddings (B, embed_dim)."""
    lm = cfg.lm_cfg()
    B, S = tokens.shape
    x = (params["embed"][tokens] + params["pos_embed"][:S][None]).astype(lm.cdt)
    positions = jnp.arange(S, dtype=jnp.int32)
    normf = _norm(lm)

    def body(x, lp):
        h, _ = L.apply_attention(
            lp["attn"], normf(lp["attn_norm"], x), positions,
            n_heads=lm.n_heads, n_kv_heads=lm.n_kv_heads, head_dim=lm.hd,
            rope_theta=lm.rope_theta, mode="bidirectional",
            compute_dtype=lm.cdt)
        x = x + h
        x = x + L.apply_mlp(lp["mlp"], normf(lp["mlp_norm"], x),
                            act=lm.act, compute_dtype=lm.cdt)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["layers"])
    x = L.apply_layernorm(params["final_norm"], x)

    if cfg.pooling == "cls":
        pooled = x[:, 0]
    else:
        m = mask.astype(jnp.float32)[..., None]
        pooled = (x.astype(jnp.float32) * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    emb = L.apply_dense(params["proj"], pooled.astype(lm.cdt), lm.cdt)
    emb = emb.astype(jnp.float32)
    return emb / jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-9)


def contrastive_loss(params: dict, batch: dict, cfg: BiEncoderConfig) -> jax.Array:
    """In-batch-negative InfoNCE. batch: q_tokens/q_mask/d_tokens/d_mask (B,S)."""
    q = encode(params, batch["q_tokens"], batch["q_mask"], cfg)
    d = encode(params, batch["d_tokens"], batch["d_mask"], cfg)
    logits = (q @ d.T) / cfg.temperature                  # (B, B)
    labels = jnp.arange(q.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def contrastive_loss_sharded(params: dict, batch: dict, cfg: BiEncoderConfig,
                             axis: str | tuple[str, ...]) -> jax.Array:
    """InfoNCE with the (B, B) logit matrix sharded over the batch axis.

    Runs inside shard_map with the batch sharded on ``axis``: embeddings are
    all-gathered once (B·d bytes — small), each device scores its local
    query rows against the full document set, psum-means the loss.
    """
    q = encode(params, batch["q_tokens"], batch["q_mask"], cfg)   # local rows
    d = encode(params, batch["d_tokens"], batch["d_mask"], cfg)
    d_all = jax.lax.all_gather(d, axis, axis=0, tiled=True)       # (B_global, dim)
    idx = jax.lax.axis_index(axis)
    local_b = q.shape[0]
    labels = idx * local_b + jnp.arange(local_b)
    logits = (q @ d_all.T) / cfg.temperature
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    return jax.lax.pmean(loss, axis)


def shard_contrastive_loss(params: dict, batch: dict, cfg: BiEncoderConfig,
                           mesh: Mesh, axis: str | tuple[str, ...] = "data"
                           ) -> jax.Array:
    """``contrastive_loss_sharded`` wrapped in shard_map over ``mesh``.

    Params replicated, batch row-sharded on ``axis``. The loss is pmean'd
    inside the body so the output is replicated — not statically provable,
    hence check_vma off.
    """
    bspec = {k: P(axis, *([None] * (jnp.ndim(v) - 1))) for k, v in batch.items()}
    fn = compat.shard_map(
        lambda p, b: contrastive_loss_sharded(p, b, cfg, axis),
        mesh=mesh, in_specs=(P(), bspec), out_specs=P(), check_vma=False)
    return fn(params, batch)
