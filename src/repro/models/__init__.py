"""Model substrate: LM transformers (dense/MoE), bi-encoder, GNN, recsys."""
