"""Recommender models: DLRM, DeepFM, AutoInt, Two-Tower retrieval.

JAX has no native EmbeddingBag and no CSR sparse — the embedding substrate
here IS part of the system:

  * ``embedding_bag``          — gather (``jnp.take``) + mean/sum over the
                                 hotness dim; single-hot is the H=1 case.
  * ``sharded_embedding_bag``  — tables row-sharded over the mesh 'model'
                                 axis; each device resolves in-range ids
                                 against its local shard (mask + take) and a
                                 ``psum`` over 'model' assembles the batch —
                                 the TPU-native expression of DLRM's
                                 model-parallel-embedding all-to-all.

Interactions: DLRM pairwise-dot, FM second-order identity
(½[(Σv)² − Σv²]), AutoInt multi-head self-attention over field tokens.

The two-tower model's candidate scoring path is the paper's exact dense-
retrieval setting: its item-side index is a ``repro.core`` DenseIndex and is
PCA-prunable offline (256 → m dims).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_dense, apply_mlp_stack, init_dense, init_mlp_stack
from repro.par import compat


# ---------------------------------------------------------------------------
# Embedding substrate
# ---------------------------------------------------------------------------


def init_embedding_tables(key, vocab_sizes: Sequence[int], dim: int,
                          dtype=jnp.float32) -> list[jax.Array]:
    keys = jax.random.split(key, len(vocab_sizes))
    return [
        (jax.random.normal(k, (int(v), dim)) / np.sqrt(dim)).astype(dtype)
        for k, v in zip(keys, vocab_sizes)
    ]


def embedding_bag(table: jax.Array, idx: jax.Array, *, combiner: str = "mean"
                  ) -> jax.Array:
    """idx: (B,) single-hot or (B, H) multi-hot -> (B, dim)."""
    if idx.ndim == 1:
        return jnp.take(table, idx, axis=0)
    g = jnp.take(table, idx.reshape(-1), axis=0).reshape(*idx.shape, -1)
    if combiner == "sum":
        return g.sum(axis=-2)
    return g.mean(axis=-2)


def sharded_embedding_bag(table: jax.Array, idx: jax.Array, *, axis: str,
                          vocab: int, combiner: str = "mean") -> jax.Array:
    """Row-sharded lookup inside shard_map.

    ``table``: local shard (vocab/num_shards, dim) — rows
    [shard*rows : (shard+1)*rows) of the logical table. ``idx`` replicated.
    Out-of-range ids resolve to 0 locally; psum assembles the true rows.
    """
    n_shards = compat.axis_size(axis)
    rows = vocab // n_shards
    shard = jax.lax.axis_index(axis)
    lo = shard * rows
    flat = idx.reshape(-1)
    local = flat - lo
    in_range = (local >= 0) & (local < rows)
    safe = jnp.clip(local, 0, rows - 1)
    g = jnp.take(table, safe, axis=0)
    g = jnp.where(in_range[:, None], g, 0.0)
    g = jax.lax.psum(g, axis)
    g = g.reshape(*idx.shape, -1)
    if idx.ndim == 1:
        return g
    return g.sum(-2) if combiner == "sum" else g.mean(-2)


# ---------------------------------------------------------------------------
# Interactions
# ---------------------------------------------------------------------------


def dot_interaction(vectors: jax.Array, *, self_interaction: bool = False
                    ) -> jax.Array:
    """DLRM pairwise dots. vectors: (B, F, E) -> (B, F·(F−1)/2)."""
    B, F, E = vectors.shape
    z = jnp.einsum("bfe,bge->bfg", vectors, vectors)
    iu, ju = np.triu_indices(F, k=0 if self_interaction else 1)
    return z[:, iu, ju]


def fm_interaction(vectors: jax.Array) -> jax.Array:
    """FM 2nd-order term: ½ Σ_e [(Σ_f v)² − Σ_f v²]. (B, F, E) -> (B,)."""
    s = vectors.sum(axis=1)
    s2 = (vectors ** 2).sum(axis=1)
    return 0.5 * (s ** 2 - s2).sum(axis=-1)


def init_autoint_attn(key, d_in: int, n_heads: int, d_attn: int, dtype=jnp.float32):
    kq, kk, kv, kr = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_in, n_heads * d_attn, dtype=dtype),
        "wk": init_dense(kk, d_in, n_heads * d_attn, dtype=dtype),
        "wv": init_dense(kv, d_in, n_heads * d_attn, dtype=dtype),
        "wr": init_dense(kr, d_in, n_heads * d_attn, dtype=dtype),  # residual proj
    }


def apply_autoint_attn(p: dict, x: jax.Array, n_heads: int, d_attn: int
                       ) -> jax.Array:
    """Self-attention over field tokens. x: (B, F, d) -> (B, F, H·d_attn)."""
    B, F, _ = x.shape
    q = apply_dense(p["wq"], x, jnp.float32).reshape(B, F, n_heads, d_attn)
    k = apply_dense(p["wk"], x, jnp.float32).reshape(B, F, n_heads, d_attn)
    v = apply_dense(p["wv"], x, jnp.float32).reshape(B, F, n_heads, d_attn)
    s = jnp.einsum("bfhd,bghd->bhfg", q, k) / np.sqrt(d_attn)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhfg,bghd->bfhd", a, v).reshape(B, F, n_heads * d_attn)
    r = apply_dense(p["wr"], x, jnp.float32)
    return jax.nn.relu(o + r)


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str = "recsys"
    kind: str = "dlrm"                      # dlrm | deepfm | autoint | two_tower
    vocab_sizes: tuple[int, ...] = ()
    embed_dim: int = 128
    n_dense: int = 0
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    # autoint
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    # deepfm
    deep_mlp: tuple[int, ...] = ()
    # two-tower
    tower_mlp: tuple[int, ...] = (1024, 512, 256)
    user_vocab: int = 2_000_000
    item_vocab: int = 1_000_000
    temperature: float = 0.05
    param_dtype: str = "float32"

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    def param_count(self) -> int:
        e = self.embed_dim
        emb = sum(self.vocab_sizes) * e
        if self.kind == "dlrm":
            dims = (self.n_dense,) + self.bot_mlp
            bot = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
            f = self.n_sparse + 1
            d_int = f * (f - 1) // 2 + self.bot_mlp[-1]
            dims = (d_int,) + self.top_mlp
            top = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
            return emb + bot + top
        if self.kind == "deepfm":
            first = sum(self.vocab_sizes)
            dims = (self.n_sparse * e,) + self.deep_mlp + (1,)
            deep = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
            return emb + first + deep
        if self.kind == "autoint":
            d_l = [e] + [self.n_heads * self.d_attn] * self.n_attn_layers
            attn = sum(4 * d_l[i] * d_l[i + 1] for i in range(self.n_attn_layers))
            out = self.n_sparse * d_l[-1]
            return emb + attn + out + 1
        # two_tower
        ue = self.user_vocab * e + self.item_vocab * e
        dims = (e,) + self.tower_mlp
        tower = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
        return ue + 2 * tower


# ---------------------------------------------------------------------------
# Models
# ---------------------------------------------------------------------------


def init_recsys(key, cfg: RecsysConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    ke, k1, k2, k3 = jax.random.split(key, 4)
    if cfg.kind == "two_tower":
        ku, ki, ktu, kti = jax.random.split(ke, 4)
        e = cfg.embed_dim
        return {
            "user_embed": (jax.random.normal(ku, (cfg.user_vocab, e)) / np.sqrt(e)).astype(pdt),
            "item_embed": (jax.random.normal(ki, (cfg.item_vocab, e)) / np.sqrt(e)).astype(pdt),
            "user_tower": init_mlp_stack(ktu, (e,) + cfg.tower_mlp, dtype=pdt),
            "item_tower": init_mlp_stack(kti, (e,) + cfg.tower_mlp, dtype=pdt),
        }
    p = {"tables": init_embedding_tables(ke, cfg.vocab_sizes, cfg.embed_dim, pdt)}
    if cfg.kind == "dlrm":
        p["bot_mlp"] = init_mlp_stack(k1, (cfg.n_dense,) + cfg.bot_mlp, dtype=pdt)
        f = cfg.n_sparse + 1
        d_int = f * (f - 1) // 2 + cfg.bot_mlp[-1]
        p["top_mlp"] = init_mlp_stack(k2, (d_int,) + cfg.top_mlp, dtype=pdt)
    elif cfg.kind == "deepfm":
        p["first_order"] = init_embedding_tables(k1, cfg.vocab_sizes, 1, pdt)
        p["deep_mlp"] = init_mlp_stack(
            k2, (cfg.n_sparse * cfg.embed_dim,) + cfg.deep_mlp + (1,), dtype=pdt)
        p["bias"] = jnp.zeros((), pdt)
    elif cfg.kind == "autoint":
        d_l = [cfg.embed_dim] + [cfg.n_heads * cfg.d_attn] * cfg.n_attn_layers
        keys = jax.random.split(k1, cfg.n_attn_layers)
        p["attn_layers"] = [
            init_autoint_attn(keys[i], d_l[i], cfg.n_heads, cfg.d_attn, pdt)
            for i in range(cfg.n_attn_layers)]
        p["out"] = init_dense(k2, cfg.n_sparse * d_l[-1], 1, bias=True, dtype=pdt)
    return p


def _lookup_all(tables: list, sparse_idx: jax.Array, *, mesh_axis: str | None = None,
                vocab_sizes: Sequence[int] = ()) -> jax.Array:
    """sparse_idx: (B, F) -> stacked embeddings (B, F, E)."""
    cols = []
    for f, table in enumerate(tables):
        idx = sparse_idx[:, f]
        if mesh_axis is None:
            cols.append(embedding_bag(table, idx))
        else:
            cols.append(sharded_embedding_bag(table, idx, axis=mesh_axis,
                                              vocab=int(vocab_sizes[f])))
    return jnp.stack(cols, axis=1)


def forward_ctr(params: dict, batch: dict, cfg: RecsysConfig, *,
                mesh_axis: str | None = None) -> jax.Array:
    """CTR logit. batch: sparse (B, F) int32 [+ dense (B, n_dense) for dlrm]."""
    emb = _lookup_all(params["tables"], batch["sparse"], mesh_axis=mesh_axis,
                      vocab_sizes=cfg.vocab_sizes)           # (B, F, E)
    return forward_ctr_from_emb(params, emb, batch, cfg)


def forward_ctr_from_emb(params: dict, emb: jax.Array, batch: dict,
                         cfg: RecsysConfig) -> jax.Array:
    """CTR logit from pre-gathered embeddings (B, F, E).

    Split out so the training step can gather rows OUTSIDE autodiff and
    differentiate w.r.t. the rows themselves (sparse-grad path — see
    ``repro.optim.rowwise``)."""
    if cfg.kind == "dlrm":
        dense_v = apply_mlp_stack(params["bot_mlp"], batch["dense"],
                                  act="relu", final_act=True)
        feats = jnp.concatenate([dense_v[:, None, :], emb], axis=1)
        inter = dot_interaction(feats)
        z = jnp.concatenate([dense_v, inter], axis=-1)
        return apply_mlp_stack(params["top_mlp"], z, act="relu")[:, 0]
    if cfg.kind == "deepfm":
        fm2 = fm_interaction(emb)
        first = sum(embedding_bag(t, batch["sparse"][:, f])[:, 0]
                    for f, t in enumerate(params["first_order"]))
        deep = apply_mlp_stack(params["deep_mlp"],
                               emb.reshape(emb.shape[0], -1), act="relu")[:, 0]
        return params["bias"] + first + fm2 + deep
    # autoint
    x = emb
    for lp in params["attn_layers"]:
        x = apply_autoint_attn(lp, x, cfg.n_heads, cfg.d_attn)
    flat = x.reshape(x.shape[0], -1)
    return apply_dense(params["out"], flat, jnp.float32)[:, 0]


def bce_loss(params: dict, batch: dict, cfg: RecsysConfig, *,
             mesh_axis: str | None = None) -> jax.Array:
    logit = forward_ctr(params, batch, cfg, mesh_axis=mesh_axis)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# -- two-tower ---------------------------------------------------------------


def user_embedding(params: dict, user_ids: jax.Array) -> jax.Array:
    e = jnp.take(params["user_embed"], user_ids, axis=0)
    u = apply_mlp_stack(params["user_tower"], e, act="relu")
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-9)


def item_embedding(params: dict, item_ids: jax.Array) -> jax.Array:
    e = jnp.take(params["item_embed"], item_ids, axis=0)
    v = apply_mlp_stack(params["item_tower"], e, act="relu")
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-9)


def two_tower_loss(params: dict, batch: dict, cfg: RecsysConfig,
                   logit_sharding=None) -> jax.Array:
    """In-batch sampled softmax with logQ correction.

    batch: user_ids (B,), item_ids (B,), item_logq (B,) — log sampling
    probability of each in-batch negative (Yi et al., RecSys'19).
    ``logit_sharding``: optional NamedSharding constraint for the (B, B)
    logit matrix — at B=65k the matrix is 17 GB and must live 2-D-sharded
    (rows over dp, cols over tp); the constraint pins XLA to that layout.
    """
    u = user_embedding(params, batch["user_ids"])
    v = item_embedding(params, batch["item_ids"])
    logits = (u @ v.T) / cfg.temperature - batch["item_logq"][None, :]
    if logit_sharding is not None:
        logits = jax.lax.with_sharding_constraint(logits, logit_sharding)
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def two_tower_loss_sharded(params: dict, batch: dict, cfg: RecsysConfig,
                           axis) -> jax.Array:
    """Sharded in-batch softmax: (B, B) logits blocked over the batch axis."""
    u = user_embedding(params, batch["user_ids"])
    v = item_embedding(params, batch["item_ids"])
    v_all = jax.lax.all_gather(v, axis, axis=0, tiled=True)
    logq_all = jax.lax.all_gather(batch["item_logq"], axis, axis=0, tiled=True)
    idx = jax.lax.axis_index(axis)
    local_b = u.shape[0]
    labels = idx * local_b + jnp.arange(local_b)
    logits = (u @ v_all.T) / cfg.temperature - logq_all[None, :]
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    return jax.lax.pmean(loss, axis)


def ctr_user_item_split(cfg: RecsysConfig) -> tuple[int, int]:
    """Field split for CTR retrieval: first half user-side, rest item-side."""
    f_user = cfg.n_sparse // 2
    return f_user, cfg.n_sparse - f_user


def ctr_retrieval_scores(params: dict, user_batch: dict, cand_sparse: jax.Array,
                         cfg: RecsysConfig) -> jax.Array:
    """Score one user context against C candidate items (CTR models).

    ``user_batch``: sparse (1, F_user) [+ dense (1, n_dense)];
    ``cand_sparse``: (C, F_item). The user fields broadcast across
    candidates; a cached-user-side variant is a §Perf optimisation.
    Returns logits (C,).
    """
    C = cand_sparse.shape[0]
    user_sp = jnp.broadcast_to(user_batch["sparse"], (C, user_batch["sparse"].shape[1]))
    batch = {"sparse": jnp.concatenate([user_sp, cand_sparse], axis=1)}
    if "dense" in user_batch:
        batch["dense"] = jnp.broadcast_to(user_batch["dense"],
                                          (C, user_batch["dense"].shape[1]))
    return forward_ctr(batch=batch, params=params, cfg=cfg)


def score_candidates(params: dict, user_ids: jax.Array, item_index: jax.Array,
                     k: int = 100) -> tuple[jax.Array, jax.Array]:
    """Retrieval: user(s) vs a precomputed (possibly PCA-pruned) item index.

    ``item_index``: (n_candidates, m) — built offline via
    ``item_embedding`` + optional ``repro.core.StaticPruner``; queries must
    be transformed by the same pruner before calling.
    """
    u = user_embedding(params, user_ids)
    from repro.core.index import _scan_topk
    return _scan_topk(item_index, u, min(k, item_index.shape[0]))
