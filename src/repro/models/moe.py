"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

TPU-native einsum dispatch (the classic GShard/T5X formulation): tokens are
split into groups of ``group_size``; within a group each expert accepts at
most ``C = group_size * top_k * capacity_factor / n_experts`` tokens.
Dispatch/combine are one-hot einsum tensors, so the whole layer is static-
shaped and SPMD-shardable:

  * expert weight tensors are (E, d, f) — sharded E→'model' when E divides
    the axis (expert parallelism, Arctic's 128 experts = 8/chip on a 16-wide
    axis), else f→'model' (tensor parallelism inside each expert, Mixtral's
    8 experts on 16 chips);
  * the dispatch einsum + expert GEMMs lower to the all-to-all / grouped
    GEMM schedule XLA emits for EP meshes.

Transient footprint per layer ≈ tokens·group_size·top_k·cf·bytes —
independent of E; group_size trades dispatch-tensor size against padding
waste. Router uses Mixtral-style top-k softmax renormalisation + the
Switch/GShard auxiliary load-balancing loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_dense


def init_moe(key, d_model: int, d_ff: int, n_experts: int, *,
             gated: bool = True, dtype=jnp.float32) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    scale_in = 1.0 / np.sqrt(d_model)
    scale_out = 1.0 / np.sqrt(d_ff)
    p = {
        "router": init_dense(kr, d_model, n_experts, dtype=dtype),
        "w1": (jax.random.normal(k1, (n_experts, d_model, d_ff)) * scale_in).astype(dtype),
        "w2": (jax.random.normal(k2, (n_experts, d_ff, d_model)) * scale_out).astype(dtype),
    }
    if gated:
        p["w3"] = (jax.random.normal(k3, (n_experts, d_model, d_ff)) * scale_in).astype(dtype)
    return p


def _route(logits: jax.Array, top_k: int, n_experts: int
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. logits: (G, S, E). Returns (gates (G,S,E) with top-k
    softmax-renormalised weights, mask (G,S,E) in {0,1}, aux_loss scalar)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_vals, top_idx = jax.lax.top_k(logits, top_k)              # (G,S,k)
    top_w = jax.nn.softmax(top_vals.astype(jnp.float32), axis=-1) # renormalise
    mask = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32)  # (G,S,k,E)
    gates = (top_w[..., None] * mask).sum(axis=2)                 # (G,S,E)
    mask_any = mask.sum(axis=2)                                   # (G,S,E)
    # Switch-style load-balance aux: E * sum_e f_e * P_e
    f = mask_any.mean(axis=(0, 1))                                # fraction routed
    P = probs.mean(axis=(0, 1))                                   # router prob mass
    aux = n_experts * jnp.sum(f * P)
    return gates, mask_any, aux


def apply_moe(p: dict, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, group_size: int = 256,
              act: str = "silu", compute_dtype=jnp.bfloat16
              ) -> tuple[jax.Array, jax.Array]:
    """MoE FFN. x: (B, S, d) -> (B, S, d), plus aux load-balance loss.

    Tokens are flattened and regrouped to ``group_size``; remainder tokens
    are padded into the last group (their gates are zeroed).
    """
    B, S, d = x.shape
    T = B * S
    g = min(group_size, T)
    G = -(-T // g)
    pad = G * g - T
    xt = x.reshape(T, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(G, g, d)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    if pad:
        valid = (jnp.arange(G * g) < T).reshape(G, g)
        logits = jnp.where(valid[..., None], logits, -1e9)
    gates, mask, aux = _route(logits, top_k, n_experts)

    capacity = max(1, int(g * top_k * capacity_factor / n_experts))
    # position of each token within its expert's buffer (per group)
    pos_in_expert = (jnp.cumsum(mask, axis=1) - 1.0) * mask       # (G,S,E)
    keep = mask * (pos_in_expert < capacity)
    gates = gates * keep
    # renormalise combine weights after capacity drops
    denom = jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    combine = (gates / denom) * (gates.sum(-1, keepdims=True) > 0)
    onehot_c = jax.nn.one_hot(pos_in_expert, capacity, dtype=jnp.float32)
    dispatch = keep[..., None] * onehot_c                          # (G,S,E,C)

    xc = xg.astype(compute_dtype)
    disp = dispatch.astype(compute_dtype)
    comb = (combine[..., None] * onehot_c).astype(compute_dtype)   # (G,S,E,C)

    expert_in = jnp.einsum("gsec,gsd->egcd", disp, xc)             # (E,G,C,d)
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["w1"].astype(compute_dtype))
    a = getattr(jax.nn, act)(h)
    if "w3" in p:
        a = a * jnp.einsum("egcd,edf->egcf", expert_in, p["w3"].astype(compute_dtype))
    expert_out = jnp.einsum("egcf,efd->egcd", a, p["w2"].astype(compute_dtype))
    yg = jnp.einsum("gsec,egcd->gsd", comb, expert_out)            # (G,S,d)

    y = yg.reshape(G * g, d)[:T].reshape(B, S, d)
    return y.astype(x.dtype), aux
