"""Decoder-only LM: dense or MoE, GQA, RoPE, optional sliding window.

Layers are *stacked* (leading L dim) and executed with ``jax.lax.scan`` so
40-layer models compile in seconds and the HLO stays mesh-partitioner-
friendly. Remat wraps the scan body (configurable policy).

Step functions:
  * ``forward_train``  — causal LM loss over (B, S) tokens
  * ``prefill``        — returns logits + stacked KV cache
  * ``decode_step``    — one token against an existing cache (full or
                         rolling sliding-window buffer)
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.models import layers as L, moe as M


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 512
    vocab: int = 1024
    head_dim: int | None = None          # default d_model // n_heads
    # MoE (n_experts=0 → dense)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_group_size: int = 256
    dense_residual: bool = False         # Arctic: parallel dense FFN + MoE
    residual_d_ff: int | None = None     # d_ff of the parallel dense branch
    moe_dp_dim: str = "ff"               # which expert dim FSDP-shards: ff|d_model
    # attention
    sliding_window: int | None = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # self-attention switches to the online-softmax blocked path above this
    # seq len: a dense (B,H,S,S) score tensor at S=4096 is already ~9 GiB
    # per device at production batch — tiles keep it to (B,H,qc,kc).
    blocked_attn_threshold: int = 2048
    attn_q_chunk: int = 1024
    attn_k_chunk: int = 1024
    # misc
    tie_embeddings: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: str = "silu"
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    aux_loss_weight: float = 0.01
    # training-step shape: gradient-accumulation microbatches + accum dtype
    microbatch: int = 1
    grad_accum_dtype: str = "float32"
    # parallelism policy: "2d" = FSDP×TP rules; "dp_only" = replicate params,
    # shard batch only (the right layout for sub-1B models — see §Perf)
    parallelism: str = "2d"
    # activation sharding anchor (NamedSharding for (B, S, d) tensors).
    # Needed with 2-D FSDP×TP param sharding: the embedding gather would
    # otherwise propagate the table's d-over-dp sharding onto activations,
    # silently un-sharding the batch dim everywhere downstream.
    act_sharding: object = None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.n_experts:
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            if self.dense_residual:
                ffn += 3 * d * (self.residual_d_ff or self.d_ff)
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * d * self.d_ff
        moe_active = self.n_layers * self.top_k * 3 * d * self.d_ff
        return full - moe_all + moe_active


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: TransformerConfig) -> dict:
    ka, km, kr = jax.random.split(key, 3)
    p = {
        "attn_norm": (L.init_rmsnorm if cfg.norm == "rmsnorm" else L.init_layernorm)(cfg.d_model, cfg.pdt),
        "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd, qkv_bias=cfg.qkv_bias, dtype=cfg.pdt),
        "mlp_norm": (L.init_rmsnorm if cfg.norm == "rmsnorm" else L.init_layernorm)(cfg.d_model, cfg.pdt),
    }
    if cfg.n_experts:
        p["moe"] = M.init_moe(km, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype=cfg.pdt)
        if cfg.dense_residual:
            p["mlp"] = L.init_mlp(kr, cfg.d_model, cfg.residual_d_ff or cfg.d_ff, dtype=cfg.pdt)
    else:
        p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, dtype=cfg.pdt)
    return p


def init_lm(key, cfg: TransformerConfig) -> dict:
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    p = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02).astype(cfg.pdt),
        "layers": stacked,
        "final_norm": (L.init_rmsnorm if cfg.norm == "rmsnorm" else L.init_layernorm)(cfg.d_model, cfg.pdt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(ku, (cfg.vocab, cfg.d_model)) * 0.02).astype(cfg.pdt)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _norm(cfg):
    return L.apply_rmsnorm if cfg.norm == "rmsnorm" else L.apply_layernorm


def _layer_fwd(cfg: TransformerConfig, lp: dict, x: jax.Array,
               positions: jax.Array, mode: str,
               kv_cache=None, cache_positions=None):
    x = _anchor(x, cfg)
    normf = _norm(cfg)
    attn_mode = "sliding" if cfg.sliding_window else "causal"
    h, new_kv = L.apply_attention(
        lp["attn"], normf(lp["attn_norm"], x), positions,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, mode=attn_mode, window=cfg.sliding_window,
        kv_cache=kv_cache, cache_positions=cache_positions,
        compute_dtype=cfg.cdt, blocked_threshold=cfg.blocked_attn_threshold,
        q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    x = x + h
    xn = normf(lp["mlp_norm"], x)
    aux = jnp.float32(0.0)
    if cfg.n_experts:
        mo, aux = M.apply_moe(lp["moe"], xn, n_experts=cfg.n_experts,
                              top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                              group_size=cfg.moe_group_size, act=cfg.act,
                              compute_dtype=cfg.cdt)
        if cfg.dense_residual:
            mo = mo + L.apply_mlp(lp["mlp"], xn, act=cfg.act, compute_dtype=cfg.cdt)
    else:
        mo = L.apply_mlp(lp["mlp"], xn, act=cfg.act, compute_dtype=cfg.cdt)
    return x + mo, aux, new_kv


def _embed(params, tokens, cfg):
    x = params["embed"][tokens].astype(cfg.cdt)
    return _anchor(x, cfg)


def _anchor(x, cfg):
    if cfg.act_sharding is not None:
        return jax.lax.with_sharding_constraint(x, cfg.act_sharding)
    return x


def _unembed(params, x, cfg):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,vd->bsv", x, table.astype(cfg.cdt)).astype(jnp.float32)


def forward_hidden(params: dict, tokens: jax.Array, cfg: TransformerConfig
                   ) -> tuple[jax.Array, jax.Array]:
    """Token ids (B, S) -> final hidden states (B, S, d) + total aux loss."""
    B, S = tokens.shape
    x = _embed(params, tokens, cfg)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, lp):
        x, aux = carry
        x, a, _ = _layer_fwd(cfg, lp, x, positions, "train")
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])
    x = _norm(cfg)(params["final_norm"], x)
    return x, aux


def forward_train(params: dict, tokens: jax.Array, labels: jax.Array,
                  cfg: TransformerConfig, logit_sharding=None,
                  loss_chunk: int = 2048) -> jax.Array:
    """Causal LM loss (mean xent over non-negative labels) + MoE aux.

    The (B, S, V) logit tensor is the training-step memory peak at scale
    (1M tokens × 49k-152k vocab = 0.2-3 TB fp32). Two mitigations:
    ``logit_sharding`` pins logits vocab-sharded over the model axis, and
    the loss streams over sequence chunks inside a remat'd scan so only a
    (B, loss_chunk, V/|tp|) slice is ever live.
    """
    x, aux = forward_hidden(params, tokens, cfg)
    B, S, _ = x.shape
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    table = table.astype(cfg.cdt)
    nchunk = max(1, S // min(loss_chunk, S))
    xs = x.reshape(B, nchunk, S // nchunk, -1).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nchunk, S // nchunk).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        xc, lc = inp
        logits = jnp.einsum("bsd,vd->bsv", xc, table).astype(jnp.float32)
        if logit_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits, logit_sharding)
        valid = lc >= 0
        lab = jnp.maximum(lc, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        tot, cnt = carry
        return (tot + jnp.sum(nll * valid), cnt + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(chunk_loss),
                                 (jnp.float32(0.0), jnp.int32(0)), (xs, ls))
    loss = tot / jnp.maximum(cnt, 1)
    return loss + cfg.aux_loss_weight * aux / max(cfg.n_layers, 1)


def prefill(params: dict, tokens: jax.Array, cfg: TransformerConfig,
            cache_len: int | None = None):
    """Process a prompt; returns (last-position logits, stacked KV cache).

    Cache layout: (L, B, S_cache, Hkv, Dh) per k/v — the scan stacks layer
    caches; ``cache_len`` > S preallocates decode capacity (static-cache
    serving: slot i == absolute position i).
    """
    B, S = tokens.shape
    x = _embed(params, tokens, cfg)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, lp):
        x, _, kv = _layer_fwd(cfg, lp, x, positions, "prefill")
        return x, kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(body_fn, x, params["layers"])
    x = _norm(cfg)(params["final_norm"], x)
    logits = _unembed(params, x[:, -1:, :], cfg)
    if cache_len is not None and cache_len > S:
        pad = ((0, 0), (0, 0), (0, cache_len - S), (0, 0), (0, 0))
        caches = (jnp.pad(caches[0], pad), jnp.pad(caches[1], pad))
    return logits[:, 0], caches


def decode_step(params: dict, kv_cache, next_token: jax.Array, pos: jax.Array,
                cfg: TransformerConfig):
    """One decode step against a static, preallocated KV cache.

    ``kv_cache``: (k, v) each (L, B, S_max, Hkv, Dh) — capacity-S_max ring of
    slots; slot i holds absolute position i. ``next_token``: (B,). ``pos``:
    scalar — the new token's absolute position; its KV is written in place at
    slot ``pos`` and attention sees slots ≤ pos (vLLM-style static cache:
    shapes and shardings are step-invariant, which is what lets the serving
    binary compile exactly once).
    Returns (logits (B, V), updated cache).
    """
    B = next_token.shape[0]
    S_max = kv_cache[0].shape[2]
    # slots strictly after `pos` are masked via a sentinel position
    idx = jnp.arange(S_max, dtype=jnp.int32)
    cache_positions = jnp.where(idx <= pos, idx, L._KPAD)
    x = _embed(params, next_token[:, None], cfg)
    positions = jnp.full((1,), pos, jnp.int32)
    normf = _norm(cfg)

    def body(x, inp):
        lp, ck, cv = inp
        h = normf(lp["attn_norm"], x)
        q = L.apply_dense(lp["attn"]["wq"], h, cfg.cdt).reshape(B, 1, cfg.n_heads, cfg.hd)
        k = L.apply_dense(lp["attn"]["wk"], h, cfg.cdt).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        v = L.apply_dense(lp["attn"]["wv"], h, cfg.cdt).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        cos, sin = L.rope_tables(positions, cfg.hd, cfg.rope_theta)
        q = L.apply_rope(q, cos[None], sin[None])
        k = L.apply_rope(k, cos[None], sin[None])
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, pos, 0, 0))
        mode = "sliding" if cfg.sliding_window else "causal"
        o = L.dense_attention(q, ck, cv, positions, cache_positions, mode,
                              cfg.sliding_window)
        o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
        x = x + L.apply_dense(lp["attn"]["wo"], o, cfg.cdt)
        xn = normf(lp["mlp_norm"], x)
        if cfg.n_experts:
            mo, _ = M.apply_moe(lp["moe"], xn, n_experts=cfg.n_experts,
                                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                                group_size=cfg.moe_group_size, act=cfg.act,
                                compute_dtype=cfg.cdt)
            if cfg.dense_residual:
                mo = mo + L.apply_mlp(lp["mlp"], xn, act=cfg.act, compute_dtype=cfg.cdt)
        else:
            mo = L.apply_mlp(lp["mlp"], xn, act=cfg.act, compute_dtype=cfg.cdt)
        return x + mo, (ck, cv)

    x, caches = jax.lax.scan(body, x, (params["layers"], kv_cache[0], kv_cache[1]))
    x = _norm(cfg)(params["final_norm"], x)
    logits = _unembed(params, x, cfg)
    return logits[:, 0], caches


def decode_step_sliding(params: dict, kv_cache, next_token: jax.Array,
                        pos: jax.Array, cfg: TransformerConfig):
    """Decode with a rolling sliding-window buffer of size W = cfg.sliding_window.

    The cache stays (L, B, W, Hkv, Dh): the new token overwrites the oldest
    slot (pos % W). Slot absolute positions are derived from ``pos``. This is
    what makes 500k-token decoding sub-quadratic *and* constant-memory for
    SWA models (Mixtral).
    """
    W = kv_cache[0].shape[2]
    slot = jnp.mod(pos, W)
    # absolute position held in each slot after the write; slots not yet
    # written (derived position < 0, i.e. pos < W) are masked via sentinel
    idx = jnp.arange(W, dtype=jnp.int32)
    cache_pos = jnp.where(idx <= slot, pos - slot + idx, pos - W + (idx - slot))
    cache_pos = jnp.where(cache_pos >= 0, cache_pos, L._KPAD)
    B = next_token.shape[0]
    x = _embed(params, next_token[:, None], cfg)
    positions = jnp.full((1,), pos, jnp.int32)

    def body(x, inp):
        lp, ck, cv = inp
        normf = _norm(cfg)
        h = normf(lp["attn_norm"], x)
        q = L.apply_dense(lp["attn"]["wq"], h, cfg.cdt).reshape(B, 1, cfg.n_heads, cfg.hd)
        k = L.apply_dense(lp["attn"]["wk"], h, cfg.cdt).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        v = L.apply_dense(lp["attn"]["wv"], h, cfg.cdt).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
        cos, sin = L.rope_tables(positions, cfg.hd, cfg.rope_theta)
        q = L.apply_rope(q, cos[None], sin[None])
        k = L.apply_rope(k, cos[None], sin[None])
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        o = L.dense_attention(q, ck, cv, positions, cache_pos, "sliding",
                              cfg.sliding_window)
        o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
        x = x + L.apply_dense(lp["attn"]["wo"], o, cfg.cdt)
        xn = normf(lp["mlp_norm"], x)
        if cfg.n_experts:
            mo, _ = M.apply_moe(lp["moe"], xn, n_experts=cfg.n_experts,
                                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                                group_size=cfg.moe_group_size, act=cfg.act,
                                compute_dtype=cfg.cdt)
            if cfg.dense_residual:
                mo = mo + L.apply_mlp(lp["mlp"], xn, act=cfg.act, compute_dtype=cfg.cdt)
        else:
            mo = L.apply_mlp(lp["mlp"], xn, act=cfg.act, compute_dtype=cfg.cdt)
        return x + mo, (ck, cv)

    x, caches = jax.lax.scan(body, x, (params["layers"], kv_cache[0], kv_cache[1]))
    x = _norm(cfg)(params["final_norm"], x)
    return _unembed(params, x, cfg)[:, 0], caches
