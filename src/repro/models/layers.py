"""Neural net building blocks, pure-functional over param pytrees.

Conventions:
  * params are nested dicts of jnp arrays; init fns take an explicit PRNG key
  * ``compute_dtype`` casts happen at apply time; params keep their storage
    dtype (fp32 master for training, bf16 for serving)
  * attention supports GQA, RoPE, optional QKV bias, causal / bidirectional /
    sliding-window masking, and a KV cache for decode
  * long sequences use a blocked (online-softmax) attention path so the
    (S, S) score matrix never materialises — the pure-JAX analogue of
    flash attention, adequate for AOT memory analysis and CPU validation
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Linear / norms
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_dense(p: dict, x: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    w = p["w"].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def apply_rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_tables(positions: jax.Array, head_dim: int, theta: float
                ) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given positions. positions: (...,) int32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, Dh); cos/sin: (..., S, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

MaskMode = Literal["causal", "bidirectional", "sliding"]


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, bias=False, dtype=dtype),
    }


_KPAD = 2 ** 30  # sentinel position marking padded key slots


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, mode: MaskMode,
               window: int | None) -> jax.Array:
    """Additive mask bias (Q, K) in fp32: 0 allowed, -inf disallowed."""
    ok = jnp.broadcast_to(k_pos[None, :] < _KPAD,
                          (q_pos.shape[0], k_pos.shape[0]))
    if mode in ("causal", "sliding"):
        ok &= q_pos[:, None] >= k_pos[None, :]
    if mode == "sliding" and window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _gqa_expand(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hkv, Dh) -> (B, S, H, Dh) by repeating each KV head."""
    hkv = k.shape[2]
    rep = n_heads // hkv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def dense_attention(q, k, v, q_pos, k_pos, mode: MaskMode, window=None):
    """Reference attention: explicit (Q, K) scores. q: (B,Sq,H,Dh)."""
    dh = q.shape[-1]
    n_heads = q.shape[2]
    k = _gqa_expand(k, n_heads)
    v = _gqa_expand(v, n_heads)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    s = s + _mask_bias(q_pos, k_pos, mode, window)[None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return o


def blocked_attention(q, k, v, q_pos, k_pos, mode: MaskMode, window=None,
                      q_chunk: int = 1024, k_chunk: int = 1024):
    """Online-softmax attention: scores exist only per (q_chunk, k_chunk) tile.

    Pure-JAX flash-attention analogue (lax.scan over KV tiles inside a scan
    over Q tiles). On real TPU the same tiling maps to a splash-attention
    Pallas kernel; here the point is the bounded working set in the compiled
    HLO (dry-run memory analysis) and CPU-verifiable numerics.
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    k = _gqa_expand(k, H)
    v = _gqa_expand(v, H)
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq, nk = -(-Sq // q_chunk), -(-Sk // k_chunk)
    # pad to tile multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * k_chunk - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * k_chunk - Sk), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, nq * q_chunk - Sq), constant_values=-1)
    kpos = jnp.pad(k_pos, (0, nk * k_chunk - Sk), constant_values=_KPAD)
    scale = 1.0 / np.sqrt(Dh)

    q_tiles = qp.reshape(B, nq, q_chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    k_tiles = kp.reshape(B, nk, k_chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    v_tiles = vp.reshape(B, nk, k_chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    qpos_t = qpos.reshape(nq, q_chunk)
    kpos_t = kpos.reshape(nk, k_chunk)

    def q_step(_, q_in):
        qt, qpt = q_in                                   # (B,qc,H,Dh), (qc,)

        def k_step(carry, k_in):
            m, l, acc = carry
            kt, vt, kpt = k_in
            s = jnp.einsum("bqhd,bkhd->bhqk", qt.astype(jnp.float32),
                           kt.astype(jnp.float32)) * scale
            s = s + _mask_bias(qpt, kpt, mode, window)[None, None]
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vt.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        # finite init so fully-masked tiles keep alpha = exp(m - m_new) finite
        m0 = jnp.full((B, H, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, Dh), jnp.float32)
        # remat both tile scans: without it autodiff saves a (B,H,qc,kc)
        # softmax residual per tile pair — the exact quadratic buffer this
        # path exists to avoid
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(k_step), (m0, l0, a0),
                                      (k_tiles, v_tiles, kpos_t))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 2, 1, 3)           # (B,qc,H,Dh)

    _, o_tiles = jax.lax.scan(jax.checkpoint(q_step), None, (q_tiles, qpos_t))
    o = o_tiles.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, Dh)
    return o[:, :Sq].astype(v.dtype)


def apply_attention(p: dict, x: jax.Array, positions: jax.Array, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    rope_theta: float, mode: MaskMode = "causal",
                    window: int | None = None,
                    kv_cache: tuple[jax.Array, jax.Array] | None = None,
                    cache_positions: jax.Array | None = None,
                    compute_dtype=jnp.bfloat16,
                    blocked_threshold: int = 8192,
                    q_chunk: int = 1024, k_chunk: int = 1024):
    """Full attention block.

    Without cache: self-attention over x ((B, S, d)) with ``positions`` (S,).
    With cache: decode — x is (B, 1, d) new tokens; cache k/v are
    (B, S_cache, Hkv, Dh); ``cache_positions`` (S_cache,) give each slot's
    absolute position (supports rolling sliding-window buffers).
    Returns (out (B,S,d), (k_all, v_all)).
    """
    B, S, _ = x.shape
    q = apply_dense(p["wq"], x, compute_dtype).reshape(B, S, n_heads, head_dim)
    k = apply_dense(p["wk"], x, compute_dtype).reshape(B, S, n_kv_heads, head_dim)
    v = apply_dense(p["wv"], x, compute_dtype).reshape(B, S, n_kv_heads, head_dim)

    cos, sin = rope_tables(positions, head_dim, rope_theta)
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])

    if kv_cache is not None:
        ck, cv = kv_cache
        k_all = jnp.concatenate([ck.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([cv.astype(v.dtype), v], axis=1)
        k_pos = jnp.concatenate([cache_positions, positions])
    else:
        k_all, v_all, k_pos = k, v, positions

    Sk = k_all.shape[1]
    attn = blocked_attention if max(S, Sk) > blocked_threshold else dense_attention
    kwargs = dict(q_chunk=q_chunk, k_chunk=k_chunk) if attn is blocked_attention else {}
    o = attn(q, k_all, v_all, positions, k_pos, mode, window, **kwargs)
    o = o.reshape(B, S, n_heads * head_dim)
    out = apply_dense(p["wo"], o, compute_dtype)
    return out, (k_all, v_all)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, *, gated: bool = True,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": init_dense(k1, d_model, d_ff, dtype=dtype),
         "w2": init_dense(k2, d_ff, d_model, dtype=dtype)}
    if gated:
        p["w3"] = init_dense(k3, d_model, d_ff, dtype=dtype)
    return p


def apply_mlp(p: dict, x: jax.Array, *, act: str = "silu",
              compute_dtype=jnp.bfloat16) -> jax.Array:
    h = apply_dense(p["w1"], x, compute_dtype)
    a = getattr(jax.nn, act)(h)
    if "w3" in p:
        a = a * apply_dense(p["w3"], x, compute_dtype)
    return apply_dense(p["w2"], a, compute_dtype)


def init_mlp_stack(key, dims: tuple[int, ...], *, bias: bool = True,
                   dtype=jnp.float32) -> list:
    """Plain MLP tower (recsys): dims = (in, h1, ..., out)."""
    keys = jax.random.split(key, len(dims) - 1)
    return [init_dense(k, dims[i], dims[i + 1], bias=bias, dtype=dtype)
            for i, k in enumerate(keys)]


def apply_mlp_stack(layers: list, x: jax.Array, *, act: str = "relu",
                    final_act: bool = False, compute_dtype=jnp.float32) -> jax.Array:
    actfn = getattr(jax.nn, act)
    n = len(layers)
    for i, p in enumerate(layers):
        x = apply_dense(p, x, compute_dtype)
        if i < n - 1 or final_act:
            x = actfn(x)
    return x
