import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod AOT dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:
512 placeholder host devices stand in for 2 TPU v5e pods; every step
function is lowered with ShapeDtypeStruct inputs (no allocation) and
compiled through the full SPMD partitioner. Sharding mismatches, OOM-scale
layouts and unsupported collectives all fail here.

Per cell the artifact JSON records:
  * memory_analysis  — per-device argument/output/temp/peak bytes
  * cost_analysis    — per-device HLO FLOPs + bytes accessed
  * collectives      — per-device bytes by collective kind, parsed from the
                       optimized HLO (the SPMD program is per-device)
  * meta             — analytic MODEL_FLOPS, param counts, cell dims

Usage:
  python -m repro.launch.dryrun --all                      # every cell, both meshes
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np


COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute", "collective-broadcast")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "token": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device bytes per collective kind from (optimized) HLO text."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", stripped)
        if not m:
            continue
        result_type, op = m.groups()
        base = op.removesuffix("-start").removesuffix("-done")
        if base in out:
            out[base]["count"] += 1
            out[base]["bytes"] += _shape_bytes(result_type)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str,
             force: bool = False, keep_hlo: bool = False) -> dict:
    from repro.configs.registry import get_arch, make_step_bundle
    from repro.launch.mesh import make_production_mesh

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    spec = get_arch(arch)
    cell = spec.cell(shape)
    record = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "status": None, "timestamp": time.time()}
    if cell.skip_reason:
        record.update(status="skipped", reason=cell.skip_reason)
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    t0 = time.time()
    try:
        with jax.default_device(jax.devices()[0]):
            bundle = make_step_bundle(arch, shape, mesh)
            with mesh:
                lowered = bundle.lower()
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower

        # global, trip-count-aware flops/bytes (cost_analysis counts scan
        # bodies once — see launch/flops.py)
        from repro.launch.flops import jaxpr_cost, hlo_collectives
        with mesh:
            acc = jaxpr_cost(bundle.fn, *bundle.args)

        mem = compiled.memory_analysis()
        mem_d = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes", "peak_memory_in_bytes"):
            if hasattr(mem, attr):
                mem_d[attr] = int(getattr(mem, attr))
        cost = compiled.cost_analysis() or {}
        cost_d = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and np.isfinite(float(v))
                  and (k in ("flops", "bytes accessed", "optimal_seconds")
                       or k.startswith("bytes accessed"))}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)          # naive (body-once) counts
        coll_trips = hlo_collectives(hlo)      # while-trip-aware counts

        record.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            n_devices=int(np.prod(mesh.devices.shape)),
            memory=mem_d, cost=cost_d, collectives=coll,
            collectives_trip_aware=coll_trips,
            accounting={"global_flops": float(acc["flops"]),
                        "global_bytes": float(acc["bytes"])},
            meta={k: (int(v) if isinstance(v, (int, np.integer)) else v)
                  for k, v in bundle.meta.items()},
            hlo_lines=len(hlo.splitlines()),
        )
        if keep_hlo:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(hlo)
    except Exception as e:  # a failed cell is a bug — record it loudly
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs.registry import cells

    if args.list:
        for spec, cell in cells():
            skip = f"  [SKIP: {cell.skip_reason}]" if cell.skip_reason else ""
            print(f"{spec.arch_id:24s} {cell.name:16s} {cell.kind:14s}{skip}")
        return

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.all:
        for spec, cell in cells():
            for m in meshes:
                todo.append((spec.arch_id, cell.name, m))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all / --list)")
        todo = [(args.arch, args.shape, m) for m in meshes]

    n_ok = n_skip = n_err = 0
    for arch, shape, m in todo:
        rec = run_cell(arch, shape, m, args.out, force=args.force,
                       keep_hlo=args.keep_hlo)
        status = rec["status"]
        if status == "ok":
            n_ok += 1
            peak = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
            print(f"OK    {arch:24s} {shape:14s} {m:8s} "
                  f"compile={rec['compile_s']:7.1f}s temp={peak:6.2f}GiB "
                  f"coll={rec['collectives']['total_bytes']/2**20:9.1f}MiB "
                  f"flops={rec['cost'].get('flops', 0):.3e}")
        elif status == "skipped":
            n_skip += 1
            print(f"SKIP  {arch:24s} {shape:14s} {m:8s} {rec['reason'][:60]}")
        else:
            n_err += 1
            print(f"ERROR {arch:24s} {shape:14s} {m:8s} {rec['error'][:120]}")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
