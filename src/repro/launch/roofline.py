"""Roofline analysis over dry-run artifacts (TPU v5e targets).

Three terms per (arch × shape × mesh), in seconds-per-step:

  compute    = global_FLOPs / (chips × 197e12)          [bf16 peak]
  memory     = analytic_HBM_bytes / (chips × 819e9)
  collective = per_device_collective_bytes / 50e9       [per-link ICI]

Sources and caveats (see EXPERIMENTS.md §Roofline for the full discussion):
  * global_FLOPs — trip-count-aware jaxpr walk (``launch/flops.py``);
    ``compiled.cost_analysis()`` counts scan bodies once, so it is recorded
    but not used. Remat recompute IS included — that's what the
    MODEL_FLOPS/HLO_FLOPS ratio surfaces.
  * HBM bytes — analytic per-family napkin model from the step bundle
    (attention interiors assumed VMEM-resident as on the Pallas target);
    the no-fusion jaxpr byte proxy is recorded as an upper bound.
  * collective bytes — while-trip-aware walk of the optimized per-device
    SPMD program; per-device bytes over per-link bandwidth ≡
    global/(chips·link_bw).

Usage: python -m repro.launch.roofline [--dir experiments/dryrun] [--mesh pod]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link


def load_records(art_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analyse(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    flops = rec["accounting"]["global_flops"]
    mem_bytes = rec["meta"].get("analytic_bytes") or 0
    coll = rec.get("collectives_trip_aware", rec.get("collectives", {}))
    coll_bytes_dev = coll.get("total_bytes", 0)

    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = mem_bytes / (chips * HBM_BW)
    t_coll = coll_bytes_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops = rec["meta"].get("model_flops") or 0
    ratio = (model_flops / flops) if flops else 0.0
    # roofline fraction: useful model flops per second at the bound vs peak
    step_time = bound
    mfu = (model_flops / step_time) / (chips * PEAK_FLOPS) if step_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant, "step_time_s": step_time,
        "model_flops": model_flops, "hlo_flops": flops,
        "useful_ratio": ratio, "roofline_fraction": mfu,
        "temp_gib": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
        "compile_s": rec.get("compile_s"),
    }


def fmt_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful (6ND/HLO) | roofline frac | temp GiB |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']*100:.1f}% "
            f"| {r['temp_gib']:.1f} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None, choices=[None, "pod", "multipod"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    rows = []
    for rec in load_records(args.dir):
        if args.mesh and rec.get("mesh") != args.mesh:
            continue
        a = analyse(rec)
        if a:
            rows.append(a)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(fmt_table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
