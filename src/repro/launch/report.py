"""Emit EXPERIMENTS.md §Dry-run / §Roofline / §Perf tables from artifacts.

  PYTHONPATH=src python -m repro.launch.report > experiments/report.md
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch.roofline import analyse, fmt_table, load_records


def dryrun_table(art_dir="experiments/dryrun") -> str:
    rows = []
    for rec in load_records(art_dir):
        if rec.get("status") == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                        f"SKIP | — | — | — | {rec['reason'][:70]} |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                        f"ERROR | — | — | — | {rec.get('error','')[:70]} |")
            continue
        mem = rec["memory"]
        coll = rec.get("collectives_trip_aware", {})
        note = ""
        temp = mem.get("temp_size_in_bytes", 0) / 2**30
        if temp > 16:
            note = "over single-chip HBM — needs multipod / see §Perf"
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | ok | "
            f"{temp:.2f} | {coll.get('total_bytes', 0)/2**30:.2f} | "
            f"{rec.get('compile_s', 0):.0f} | {note} |")
    hdr = ("| arch | shape | mesh | status | temp GiB/dev | collective "
           "GiB/dev/step | compile s | note |\n" + "|" + "---|" * 8)
    return hdr + "\n" + "\n".join(sorted(rows))


def hillclimb_tables(hc_dir="experiments/hillclimb") -> str:
    out = []
    for path in sorted(glob.glob(os.path.join(hc_dir, "*.json"))):
        cell = os.path.basename(path).replace(".json", "")
        with open(path) as f:
            log = json.load(f)
        out.append(f"\n#### {cell}\n")
        out.append("| variant | hypothesis | compute s | memory s | "
                   "collective s | dominant | step s | vs baseline |")
        out.append("|" + "---|" * 8)
        base = next((e for e in log if e["status"] == "ok"), None)
        for e in log:
            if e["status"] != "ok":
                out.append(f"| {e['variant']} | {e['hypothesis'][:60]} | "
                           f"ERROR {e.get('error','')[:40]} |||||||")
                continue
            speed = (base["step_time_s"] / e["step_time_s"]
                     if base and e["step_time_s"] else 0)
            out.append(
                f"| {e['variant']} | {e['hypothesis'][:60]}… | "
                f"{e['t_compute_s']:.2e} | {e['t_memory_s']:.2e} | "
                f"{e['t_collective_s']:.2e} | {e['dominant']} | "
                f"{e['step_time_s']:.2e} | {speed:.2f}x |")
    return "\n".join(out)


def main() -> None:
    print("## §Dry-run (all cells × both meshes)\n")
    print(dryrun_table())
    print("\n## §Roofline (single-pod, 256 chips)\n")
    rows = [a for a in (analyse(r) for r in load_records("experiments/dryrun"))
            if a and a["mesh"] == "pod"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(fmt_table(rows))
    print("\n## §Roofline (multi-pod, 512 chips)\n")
    rows = [a for a in (analyse(r) for r in load_records("experiments/dryrun"))
            if a and a["mesh"] == "multipod"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(fmt_table(rows))
    print("\n## §Perf hillclimb logs\n")
    print(hillclimb_tables())


if __name__ == "__main__":
    main()
