"""Trip-count-aware cost accounting for the roofline.

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE (verified on
this container: an 8-step scanned matmul reports 1/8 the FLOPs of its
unrolled twin). Every model here scans over layers, so we do our own
accounting:

  * ``jaxpr_cost(fn, *args)`` walks the post-AD jaxpr: dot_general FLOPs
    from shapes, scan bodies × length, pjit/remat/custom-vjp recursion.
    Counts are GLOBAL (pre-partitioning shapes) — divide by total chips for
    the per-chip roofline. Remat recompute is included (it appears in the
    AD jaxpr), which is exactly what the MODEL_FLOPS/HLO_FLOPS ratio is
    meant to expose.
  * bytes is an HBM-traffic proxy: operand+result bytes of memory-relevant
    ops (dots, gathers/scatters, reduces, concats) — i.e. assuming perfect
    elementwise fusion. Good for term *comparison* and optimisation deltas,
    not absolute bandwidth prediction.
  * ``hlo_collectives(text)`` walks the optimized per-device HLO
    computation graph and multiplies collectives inside while-loop bodies
    by the loop trip count (parsed from the loop condition constant) —
    without this, MoE all-to-alls inside the layer loop are undercounted
    by n_layers.
"""
from __future__ import annotations

import re
from collections import defaultdict

import jax
import numpy as np
from jax import core as jcore

from repro.par import compat


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


_MEM_OPS = {"dot_general", "gather", "scatter", "scatter-add", "scatter_add",
            "dynamic_slice", "dynamic_update_slice", "concatenate", "take",
            "reduce_sum", "reduce_max", "reduce_min", "argmax", "argmin",
            "cumsum", "sort", "top_k", "conv_general_dilated"}


def _eqn_cost(eqn) -> tuple[float, float]:
    """(flops, bytes) for a single first-order eqn."""
    prim = eqn.primitive.name
    out_avals = [v.aval for v in eqn.outvars]
    in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
    flops = 0.0
    byts = 0.0
    if prim == "dot_general":
        dnums = eqn.params["dimension_numbers"]
        (lc, _), _ = dnums
        lhs = in_avals[0]
        k = int(np.prod([lhs.shape[d] for d in lc])) if lc else 1
        out_elems = int(np.prod(out_avals[0].shape)) if out_avals[0].shape else 1
        flops = 2.0 * out_elems * k
    elif prim == "conv_general_dilated":
        out_elems = int(np.prod(out_avals[0].shape))
        rhs = in_avals[1]
        flops = 2.0 * out_elems * int(np.prod(rhs.shape[1:]))
    elif prim in ("reduce_sum", "reduce_max", "reduce_min", "cumsum",
                  "argmax", "argmin", "reduce_and", "reduce_or"):
        flops = float(np.prod(in_avals[0].shape)) if in_avals else 0.0
    elif prim in ("exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                  "sin", "cos", "pow", "integer_pow", "add", "sub", "mul",
                  "div", "max", "min", "select_n"):
        flops = float(np.prod(out_avals[0].shape)) if out_avals and out_avals[0].shape else 0.0
    if prim in _MEM_OPS:
        byts = float(sum(_aval_bytes(a) for a in in_avals)
                     + sum(_aval_bytes(a) for a in out_avals))
    return flops, byts


def _walk(jaxpr: jcore.Jaxpr) -> tuple[float, float]:
    flops = 0.0
    byts = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub = None
        mult = 1.0
        if prim == "scan":
            sub = eqn.params["jaxpr"].jaxpr
            mult = float(eqn.params["length"])
        elif prim == "while":
            sub = eqn.params["body_jaxpr"].jaxpr
            mult = 1.0  # unknown trips; not used by our step fns
        elif prim == "cond":
            subs = [b.jaxpr for b in eqn.params["branches"]]
            costs = [_walk(s) for s in subs]
            flops += max(c[0] for c in costs)
            byts += max(c[1] for c in costs)
            continue
        elif prim in ("jit", "pjit", "closed_call", "core_call", "remat",
                      "remat2", "checkpoint", "custom_jvp_call",
                      "custom_vjp_call", "custom_vjp_call_jaxpr", "xla_call"):
            p = eqn.params
            cj = (p.get("jaxpr") or p.get("call_jaxpr") or p.get("fun_jaxpr"))
            if cj is not None:
                sub = cj.jaxpr if hasattr(cj, "jaxpr") else cj
        elif prim == "shard_map":
            sub = compat.shard_map_eqn_body(eqn)
            if sub is not None:
                # shard_map body shapes are per-shard: scale back to global
                mult = compat.shard_map_eqn_device_count(eqn)
        if sub is not None:
            f, b = _walk(sub)
            flops += mult * f
            byts += mult * b
        else:
            f, b = _eqn_cost(eqn)
            flops += f
            byts += b
    return flops, byts


def jaxpr_cost(fn, *args) -> dict:
    """Global FLOPs + HBM-byte proxy for fn(*args) (args may be SDS)."""
    closed = jax.make_jaxpr(fn)(*args)
    flops, byts = _walk(closed.jaxpr)
    return {"flops": flops, "bytes": byts}


# ---------------------------------------------------------------------------
# While-trip-aware collective accounting over optimized HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute", "collective-broadcast")


def _type_bytes(t: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(t):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def hlo_collectives(hlo: str) -> dict:
    """Per-device collective bytes/counts, multiplying while-loop bodies.

    Returns {kind: {count, bytes}, total_bytes, total_count}.
    """
    # 1. split into computations. Headers sit at column 0:
    #    ``%name (args) -> type {`` / ``ENTRY %name (args) -> type {``;
    #    bodies are indented; a computation ends at a column-0 ``}``.
    comps: dict[str, list[str]] = {}
    entry_name = None
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry_name = cur
            continue
        if line.startswith("}"):
            cur = None
        else:
            comps[cur].append(line)

    # 2. per-computation direct collective cost + sub-calls
    direct: dict[str, dict] = {}
    calls: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for name, lines in comps.items():
        d = defaultdict(lambda: [0, 0])
        for line in lines:
            s = line.strip()
            m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
            if m:
                rtype, op = m.groups()
                if op.endswith("-done"):
                    continue  # the matching -start already counted
                base = op.removesuffix("-start")
                if base in _COLL:
                    d[base][0] += 1
                    d[base][1] += _type_bytes(rtype)
            # while loops: find body + trip count from condition
            mw = re.search(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)", s)
            if mw:
                cond, body = mw.groups()
                trips = _trip_count(comps.get(cond, []))
                calls[name].append((body, trips))
            elif s and "while(" not in s:
                # direct calls / fusions that might hold collectives
                for mc in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", s):
                    calls[name].append((mc.group(1), 1.0))
        direct[name] = {k: tuple(v) for k, v in d.items()}

    # 3. resolve totals bottom-up (memoised)
    memo: dict[str, dict] = {}

    def total(name: str, depth=0) -> dict:
        if name in memo or depth > 50:
            return memo.get(name, {})
        out = defaultdict(lambda: [0, 0])
        for k, (c, b) in direct.get(name, {}).items():
            out[k][0] += c
            out[k][1] += b
        for child, mult in calls.get(name, []):
            sub = total(child, depth + 1)
            for k, v in sub.items():
                if not isinstance(v, dict):
                    continue
                out[k][0] += mult * v["count"]
                out[k][1] += mult * v["bytes"]
        res = {k: {"count": int(v[0]), "bytes": int(v[1])} for k, v in out.items()}
        memo[name] = res
        return res

    entry = entry_name
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda n: len(comps[n])) if comps else ""

    res = total(entry)
    full = {k: res.get(k, {"count": 0, "bytes": 0}) for k in _COLL}
    full["total_bytes"] = int(sum(v["bytes"] for v in res.values()))
    full["total_count"] = int(sum(v["count"] for v in res.values()))
    return full


def _trip_count(cond_lines: list[str]) -> float:
    """Trip count from a while condition: compare(iter, constant(N))."""
    consts = []
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    return float(max(consts)) if consts else 1.0
