"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — device count is locked at first jax init, and
only ``dryrun.py`` forces the 512-placeholder-device environment.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
