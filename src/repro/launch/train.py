"""Training driver: any registered arch, fault-tolerant, checkpoint/restart.

Production semantics in one process:
  * builds the step bundle for (arch, shape) on the requested mesh
  * auto-resume: ``--resume auto`` restores the latest complete checkpoint
    (elastic — the mesh may differ from the one that wrote it)
  * async checkpoints every ``--ckpt-every`` steps, keep-N GC
  * deterministic data: batch t is a pure function of (seed, t), so a
    restarted/rescaled job replays the identical batch sequence
  * straggler mitigation at the input layer: host batches are prefetched on
    a background thread, so a slow host never stalls the device step

CPU-friendly: ``--smoke`` swaps in the arch's reduced config and a host mesh
so the full driver path (init → step loop → checkpoint → resume) runs in CI.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 20 --ckpt-every 10 --ckpt-dir /tmp/ckpt --resume auto
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.configs.base import ArchSpec, ShapeCell
from repro.configs.steps import BUNDLE_BUILDERS
from repro.data import recsys as rdata, tokens as tdata
from repro.data.graph import batched_molecules
from repro.launch.mesh import make_host_mesh, make_production_mesh


def _smoke_spec(arch_id: str) -> ArchSpec:
    spec = registry.get_arch(arch_id)
    cfg = registry.get_smoke_cfg(arch_id)
    if spec.family == "lm":
        cell = ShapeCell("smoke", "train", dict(seq_len=32, global_batch=8))
    elif spec.family == "gnn":
        cell = ShapeCell("smoke", "train",
                         dict(n_nodes=120, n_edges=480, batch=4, d_feat=cfg.d_in))
    elif spec.family == "biencoder":
        cell = ShapeCell("smoke", "train", dict(seq_len=16, global_batch=8))
    else:
        cell = ShapeCell("smoke", "train", dict(batch=32))
    return dataclasses.replace(spec, cfg=cfg, shapes=(cell,),
                               optimizer=spec.optimizer)


def make_batch_fn(spec: ArchSpec, cell: ShapeCell, seed: int):
    d = cell.dims
    fam = spec.family
    if fam == "lm":
        return lambda t: tdata.token_batch(
            seed, t, batch=d["global_batch"], seq_len=d["seq_len"],
            vocab=spec.cfg.vocab)
    if fam == "biencoder":
        return lambda t: tdata.pair_batch(
            seed, t, batch=d["global_batch"], seq_len=d["seq_len"],
            vocab=spec.cfg.vocab)
    if fam == "gnn":
        cfg = spec.cfg

        def gnn_batch(t):
            b = batched_molecules(d.get("batch", 4),
                                  d["n_nodes"] // d.get("batch", 4),
                                  d["n_edges"] // d.get("batch", 4),
                                  cfg.d_in, cfg.d_edge_in, seed=seed + t)
            N, E = b["nodes"].shape[0], b["edge_index"].shape[1]
            b["targets"] = np.zeros((N, cfg.d_out), np.float32)
            b["edge_mask"] = np.ones((E,), np.float32)
            b["node_mask"] = np.ones((N,), np.float32)
            b["edges"] = b["edges"][:, :cfg.d_edge_in]
            return b
        return gnn_batch
    # recsys
    cfg = spec.cfg
    if cfg.kind == "two_tower":
        return lambda t: rdata.two_tower_batch(
            seed, t, batch=d["batch"], user_vocab=cfg.user_vocab,
            item_vocab=cfg.item_vocab)
    return lambda t: rdata.ctr_batch(
        seed, t, batch=d["batch"], vocab_sizes=cfg.vocab_sizes,
        n_dense=cfg.n_dense)


def train(arch: str, *, steps: int, smoke: bool, ckpt_dir: str | None,
          ckpt_every: int, resume: str, seed: int, shape: str | None,
          multi_pod: bool = False, log_every: int = 10) -> dict:
    spec = _smoke_spec(arch) if smoke else registry.get_arch(arch)
    cell = spec.shapes[0] if shape is None else spec.cell(shape)
    mesh = make_host_mesh() if smoke else make_production_mesh(multi_pod=multi_pod)

    bundle = BUNDLE_BUILDERS[spec.family](spec, cell, mesh)
    step_fn = bundle.jitted()

    # real init (smoke / small runs). For production this is sharded-init.
    with mesh:
        if spec.family == "lm":
            from repro.models.transformer import init_lm
            params = init_lm(jax.random.PRNGKey(seed), spec.cfg)
        elif spec.family == "gnn":
            from repro.models.gnn import init_gnn
            params = init_gnn(jax.random.PRNGKey(seed), spec.cfg)
        elif spec.family == "biencoder":
            from repro.models.biencoder import init_biencoder
            params = init_biencoder(jax.random.PRNGKey(seed), spec.cfg)
        else:
            from repro.models.recsys import init_recsys
            params = init_recsys(jax.random.PRNGKey(seed), spec.cfg)
        from repro.configs.steps import _opt_pack
        opt_init, _ = _opt_pack(spec.optimizer)
        opt_state = opt_init(params)

    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume == "auto" and mgr.latest_step() is not None:
        (params, opt_state), start_step = mgr.restore(
            (params, opt_state), mesh=mesh)
        print(f"[train] resumed from step {start_step}")

    batch_fn = make_batch_fn(spec, cell, seed)
    prefetch = tdata.Prefetcher(batch_fn, start_step=start_step, depth=2)
    losses = []
    t0 = time.time()
    try:
        for i in range(start_step, start_step + steps):
            step_idx, host_batch = next(prefetch)
            batch = jax.tree.map(jnp.asarray, host_batch)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {i}")
            if log_every and (i + 1) % log_every == 0:
                dt = (time.time() - t0) / max(1, len(losses))
                print(f"[train] step {i+1:5d} loss {loss:.4f} ({dt*1e3:.0f} ms/step)")
            if mgr and ckpt_every and (i + 1) % ckpt_every == 0:
                mgr.save(i + 1, (params, opt_state),
                         spec_tree=(bundle.in_specs[0], bundle.in_specs[1]))
    finally:
        prefetch.close()
        if mgr:
            mgr.wait()
    return {"final_loss": losses[-1] if losses else None,
            "losses": losses, "steps_run": len(losses),
            "params": params, "opt_state": opt_state}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, smoke=args.smoke,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                resume=args.resume, seed=args.seed, shape=args.shape,
                multi_pod=args.multi_pod)
    print(f"[train] done: {out['steps_run']} steps, "
          f"final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
