"""Retrieval serving driver: batched queries against a PCA-pruned index.

The paper's online path, end to end:
  1. load the offline artefacts (PCA transform W_m + pruned index D̂)
  2. batch incoming queries (micro-batching queue with a latency deadline)
  3. q̂ = W_mᵀ q  (the only added per-query cost: O(dm))
  4. fused score+top-k scan over the (sharded) index
  5. return doc ids + scores

``--compare-full`` serves the unpruned index side by side and reports the
measured speedup vs the O(d/m) prediction.

``--sharded`` row-shards the pruned index over a mesh of every visible
device and serves through ``ShardedDenseIndex`` (local fused scan + tiny
global top-k merge). On a CPU-only host, ``--host-devices N`` forces an
N-way mesh via ``XLA_FLAGS=--xla_force_host_platform_device_count`` —
the same code path a TPU pod takes, minus the speed. ``--backend pallas``
selects the fused score-and-select kernel for the (per-shard) scan.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --n-docs 50000 --dim 256 \
      --cutoff 0.5 --queries 256 --batch 32
  PYTHONPATH=src python -m repro.launch.serve --sharded --host-devices 4 \
      --backend pallas
"""
from __future__ import annotations

import argparse
import os
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex, ShardedDenseIndex, StaticPruner
from repro.data.synthetic import make_dataset


class BatchingQueue:
    """Micro-batching: collect up to ``max_batch`` requests or flush at the
    latency deadline — the standard online-serving pattern."""

    def __init__(self, max_batch: int = 32, deadline_ms: float = 2.0):
        self.q: queue.Queue = queue.Queue()
        self.max_batch = max_batch
        self.deadline = deadline_ms / 1e3

    def submit(self, qvec: np.ndarray) -> "queue.Queue":
        reply: queue.Queue = queue.Queue(maxsize=1)
        self.q.put((qvec, reply))
        return reply

    def next_batch(self) -> tuple[np.ndarray, list] | None:
        try:
            first = self.q.get(timeout=0.5)
        except queue.Empty:
            return None
        items = [first]
        t0 = time.time()
        while len(items) < self.max_batch and (time.time() - t0) < self.deadline:
            try:
                items.append(self.q.get_nowait())
            except queue.Empty:
                time.sleep(0.0002)
        vecs = np.stack([x[0] for x in items])
        replies = [x[1] for x in items]
        return vecs, replies


class RetrievalServer:
    """Batched query server over a DenseIndex or ShardedDenseIndex.

    Both index types expose ``search(q, k) -> (scores, ids)``; the sharded
    one fans the batch out over the mesh and merges per-shard top-k, so the
    server loop is layout-agnostic.
    """

    def __init__(self, index: DenseIndex | ShardedDenseIndex,
                 pruner: StaticPruner | None,
                 k: int = 10, max_batch: int = 32):
        self.index = index
        self.pruner = pruner
        self.k = k
        self.batcher = BatchingQueue(max_batch=max_batch)
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    def _loop(self):
        while not self._stop.is_set():
            item = self.batcher.next_batch()
            if item is None:
                continue
            vecs, replies = item
            q = jnp.asarray(vecs)
            if self.pruner is not None:
                q = self.pruner.transform_queries(q)
            scores, ids = self.index.search(q, k=self.k)
            scores = np.asarray(scores)
            ids = np.asarray(ids)
            for i, r in enumerate(replies):
                r.put((scores[i], ids[i]))

    def query(self, qvec: np.ndarray, timeout: float = 10.0):
        return self.batcher.submit(qvec).get(timeout=timeout)

    def close(self):
        self._stop.set()
        self._worker.join(timeout=2.0)


def _force_host_devices(n: int) -> None:
    """Ask XLA for an n-way host platform. Only effective before the JAX
    backend initialises — call first thing in main, before any array op."""
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=50000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--cutoff", type=float, default=0.5)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--compare-full", action="store_true")
    ap.add_argument("--sharded", action="store_true",
                    help="row-shard the index over a mesh of every device")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force an N-way host-platform mesh via XLA_FLAGS "
                         "(default: 4 when --sharded; no-op on non-CPU "
                         "platforms or once JAX is initialised)")
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp",
                    help="scan backend for the (per-shard) score+top-k")
    ap.add_argument("--quantize-int8", action="store_true")
    args = ap.parse_args()

    _force_host_devices(args.host_devices or (4 if args.sharded else 0))

    print(f"[serve] building corpus n={args.n_docs} d={args.dim}")
    ds = make_dataset("tasb", n_docs=args.n_docs, d=args.dim,
                      query_sets=("dl19",))
    D = jnp.asarray(ds.docs)
    Q = np.asarray(ds.queries["dl19"])
    Q = np.tile(Q, (max(1, args.queries // len(Q) + 1), 1))[:args.queries]

    pruner = StaticPruner(cutoff=args.cutoff).fit(D)
    pruned = pruner.prune_index(D)
    if args.sharded:
        ndev = jax.device_count()
        mesh = jax.make_mesh((ndev,), ("data",))
        index = ShardedDenseIndex.build(pruned, mesh,
                                        quantize_int8=args.quantize_int8,
                                        backend=args.backend)
        print(f"[serve] sharded index: {index.n} x {index.dim} over "
              f"{ndev} devices ({index.nbytes/2**20:.1f} MiB, "
              f"backend={args.backend})")
    else:
        index = DenseIndex.build(pruned, quantize_int8=args.quantize_int8,
                                 backend=args.backend)
        print(f"[serve] pruned index: {index.n} x {index.dim} "
              f"({index.nbytes/2**20:.1f} MiB)")

    server = RetrievalServer(index, pruner, k=args.k, max_batch=args.batch)
    lat = []
    t0 = time.time()
    for i in range(args.queries):
        t = time.time()
        server.query(Q[i])
        lat.append(time.time() - t)
    wall = time.time() - t0
    server.close()
    lat_ms = np.array(lat) * 1e3
    print(f"[serve] pruned: {args.queries / wall:.1f} qps  "
          f"p50={np.percentile(lat_ms, 50):.2f}ms "
          f"p99={np.percentile(lat_ms, 99):.2f}ms")

    if args.compare_full:
        full = DenseIndex.build(D)
        server2 = RetrievalServer(full, None, k=args.k, max_batch=args.batch)
        t0 = time.time()
        for i in range(args.queries):
            server2.query(Q[i])
        wall_full = time.time() - t0
        server2.close()
        print(f"[serve] full:   {args.queries / wall_full:.1f} qps  "
              f"speedup={wall_full / wall:.2f}x "
              f"(O(d/m) predicts {args.dim / pruner.kept_dims:.2f}x)")


if __name__ == "__main__":
    main()
