"""Retrieval serving driver: batched queries against a PCA-pruned index.

The paper's online path, end to end:
  1. load the offline artefacts (PCA transform W_m + pruned index D̂)
  2. batch incoming queries (micro-batching queue with a latency deadline)
  3. one fused dispatch: q̂ = W_mᵀ q, int8 scale fold, score+top-k scan
     (``search_projected`` — projection never leaves the compiled graph)
  4. return doc ids + scores

The worker is a two-thread pipeline (``pipeline_depth`` >= 2, the
default): a *stager* assembles batches and enqueues the fused search —
JAX dispatch is asynchronous, so this returns before the device finishes —
and a *completer* blocks only on the *oldest* in-flight batch's
device-to-host transfer and posts replies. Up to ``pipeline_depth``
batches are in flight, so batch N+1's assembly, H2D transfer and dispatch
overlap batch N's compute instead of serialising behind its D2H.
``pipeline_depth<=1`` is the old synchronous loop (same math, same
compiled fn — kept for the sync-vs-pipelined benchmark rows).

``--compare-full`` serves the unpruned index side by side and reports the
measured speedup vs the O(d/m) prediction.

``--sharded`` row-shards the pruned index over a mesh of every visible
device and serves through ``ShardedDenseIndex`` (local fused scan + tiny
global top-k merge). On a CPU-only host, ``--host-devices N`` forces an
N-way mesh via ``XLA_FLAGS=--xla_force_host_platform_device_count`` —
the same code path a TPU pod takes, minus the speed. ``--backend pallas``
selects the fused score-and-select kernel for the (per-shard) scan.
``--merge hierarchical`` factors the device count into a 2-D mesh and
merges per-shard candidates in two all-gather stages (k·(a+b) candidates
per device instead of k·a·b).

``--save-index DIR`` persists the offline artifact (PCA state + pruned
vectors + int8 scale) through ``repro.core.store``; ``--load-index DIR``
serves from it — no PCA refit, no index rebuild, and the index is
host-streamed onto the device(s) (per-shard when ``--sharded``). The
cold-start time (open store -> first answered query) is printed.

``--live-append R`` wraps the index in a ``SegmentedIndex`` and appends
synthetic documents at R rows/s WHILE serving: each append builds a new
segment set (open delta with its own int8 scale) and installs it into the
running server atomically between batches (``swap_index``), then a final
compaction rebuilds base+deltas into one fresh base mid-serve — the full
live-index lifecycle under traffic, zero steady-state recompiles.
``--bucket-batches`` pads partial batches to the next bucket in
{8, 16, …, max_batch} instead of always max_batch (less pad compute at
low load for a handful of extra compiles).

``--paged`` serves through a ``PagedIndex``: the index lives in
fixed-size pages behind an int32 indirection table, so live appends,
delta promotion, compaction and eviction are page-pointer swaps — no
array rebuilds, no per-segment-count recompiles. ``--page-rows R`` sets
the page geometry (rows per page) and ``--page-pool P`` caps the
device-resident pool at P pages: an index larger than the pool keeps its
overflow pages host-side and streams them through the double-buffered
DMA pipeline on demand (oversubscription). Composes with
``--live-append`` (the updater mirrors page lifecycle ops to the store),
``--cascade`` (both resolutions page), and ``--save-index`` /
``--load-index`` (the page map rides the manifest; a paged artifact is
auto-detected). ``--sharded`` is not supported.

``--cascade M:N`` serves through a two-resolution ``CascadeIndex``:
a coarse scan over the first M PCA dims (int8) keeps N·k candidates per
query, then one small exact rescore at full m picks the final top-k —
bit-identical to the single-resolution search whenever N·k >= n, ~24x
fewer scanned bytes otherwise. Composes with ``--live-append`` (both
resolutions grow and swap as one object) and ``--save-index`` /
``--load-index`` (the coarse view rides the same store as a
``resolutions`` manifest entry); ``--sharded`` is not supported.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --n-docs 50000 --dim 256 \
      --cutoff 0.5 --queries 256 --batch 32
  PYTHONPATH=src python -m repro.launch.serve --sharded --host-devices 4 \
      --backend pallas --merge hierarchical
  PYTHONPATH=src python -m repro.launch.serve --pipeline-depth 4 \
      --open-loop 200            # Poisson arrivals at 200 qps, p50/p95/p99
  PYTHONPATH=src python -m repro.launch.serve --pipeline-depth 1 \
      --open-loop 200            # same load through the synchronous loop
  PYTHONPATH=src python -m repro.launch.serve --n-docs 50000 \
      --quantize-int8 --save-index /tmp/idx
  PYTHONPATH=src python -m repro.launch.serve --load-index /tmp/idx --sharded
  PYTHONPATH=src python -m repro.launch.serve --live-append 300 \
      --open-loop 200            # segmented index: append while serving,
                                 # atomic swaps, final mid-serve compaction
  PYTHONPATH=src python -m repro.launch.serve --bucket-batches \
      --open-loop 50             # low load: pad to {8,16,32}, not max_batch
  PYTHONPATH=src python -m repro.launch.serve --n-docs 100000 --dim 768 \
      --cascade 64:8             # coarse m=64 int8 scan -> exact rescore
  PYTHONPATH=src python -m repro.launch.serve --cascade 64:8 \
      --live-append 300          # cascade + live appends: both resolutions
                                 # grow and swap atomically as one object
  PYTHONPATH=src python -m repro.launch.serve --paged --page-rows 256 \
      --live-append 300          # paged index: appends/compaction are
                                 # page-pointer swaps, zero recompiles
  PYTHONPATH=src python -m repro.launch.serve --load-index /tmp/idx \
      --paged --page-pool 96     # oversubscribed: pool capped at 96
                                 # pages, the rest stream from host
"""
from __future__ import annotations

import argparse
import queue
import threading
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CascadeIndex, DenseIndex, IndexStore, ShardedDenseIndex, StaticPruner
from repro.core.store import save_index
from repro.data.synthetic import make_dataset
from repro.util import force_host_device_count


class TimedOut(RuntimeError):
    """A reply's deadline expired before its batch posted. Delivered as the
    reply payload (and re-raised by ``query``) — an explicit timeout, never
    a silently dropped request."""


class Reply(queue.Queue):
    """Single-slot reply future for one submitted query.

    ``completed_at`` is stamped by the completer (``perf_counter``) the
    instant the batch's results post — BEFORE the client is woken. Latency
    accounting reads the stamp instead of the collector's own clock, so it
    no longer assumes replies complete in submission (FIFO) order: a
    multi-priority scheduler, a mid-drain index swap, or a slow collector
    can reorder/delay observation without corrupting the measurement.

    ``deadline`` (absolute ``perf_counter`` time, or None) lets the server
    expire the reply with a ``TimedOut`` payload if its batch has not
    posted in time. Because a reply can then race its own expiry, all
    delivery goes through ``resolve``: first writer wins, later writers
    are no-ops — a posted result never overwrites a timeout or vice versa,
    and nobody ever blocks on the single reply slot.
    """

    def __init__(self, deadline: float | None = None):
        super().__init__(maxsize=1)
        self.completed_at: float | None = None
        self.deadline = deadline
        self.done = False
        self._claim = threading.Lock()

    def resolve(self, payload, t: float | None = None) -> bool:
        """Deliver ``payload`` exactly once; returns False if a prior
        resolution (result, timeout, or worker crash) already won."""
        with self._claim:
            if self.done:
                return False
            self.done = True
            self.completed_at = t
        self.put_nowait(payload)
        return True


class BatchingQueue:
    """Micro-batching: collect up to ``max_batch`` requests, flush at a
    latency deadline — the standard online-serving pattern.

    All waits park on one condition variable: the old implementation spun
    ``get_nowait`` + 200 µs sleeps for the whole deadline window on every
    batch and woke every 0.5 s at idle, burning CPU for nothing. An idle
    server now costs ~zero CPU (pinned by tests/test_serve_pipeline.py).

    ``next_batch(want_full=...)`` is the pipelined scheduler's hook: while
    the predicate holds (the device is still chewing on earlier batches),
    the collector waits for a *full* batch instead of flushing at the
    deadline — queued requests lose no latency (the device couldn't start
    them anyway) and the batch that is dispatched ahead carries no padding.
    The moment the predicate flips (device idle — see ``kick()``), the
    deadline policy resumes and a partial batch flushes immediately.
    """

    def __init__(self, max_batch: int = 32, deadline_ms: float = 2.0):
        self.max_batch = max_batch
        self.deadline = deadline_ms / 1e3
        self._items: deque = deque()
        self._cv = threading.Condition()

    def submit(self, qvec: np.ndarray,
               deadline: float | None = None) -> "Reply":
        reply = Reply(deadline=deadline)
        with self._cv:
            self._items.append((qvec, reply))
            self._cv.notify_all()
        return reply

    def kick(self) -> None:
        """Wake every waiter so it re-evaluates its predicate (called on
        server close and whenever the device drains to idle)."""
        with self._cv:
            self._cv.notify_all()

    def drain(self) -> list:
        """Remove and return every pending (vec, reply) pair — used to
        fail-fast outstanding requests when a worker thread dies."""
        with self._cv:
            items = list(self._items)
            self._items.clear()
        return items

    def empty(self) -> bool:
        with self._cv:
            return not self._items

    def next_batch(self, timeout: float = 30.0,
                   stop: threading.Event | None = None,
                   want_full=None) -> tuple[np.ndarray, list] | None:
        with self._cv:
            ready = self._cv.wait_for(
                lambda: self._items or (stop is not None and stop.is_set()),
                timeout=timeout)
            if not ready or not self._items:
                return None
            flush_at = time.monotonic() + self.deadline
            while len(self._items) < self.max_batch:
                if want_full is not None and want_full():
                    # device busy: hold out for a full batch; a kick() or
                    # new submit re-evaluates (1 s backstop vs lost wakeups)
                    self._cv.wait(timeout=1.0)
                    continue
                rem = flush_at - time.monotonic()
                if rem <= 0 or not self._cv.wait(timeout=rem):
                    break
            items = [self._items.popleft()
                     for _ in range(min(self.max_batch, len(self._items)))]
        vecs = np.stack([x[0] for x in items])
        replies = [x[1] for x in items]
        return vecs, replies


class RetrievalServer:
    """Batched query server over a DenseIndex or ShardedDenseIndex.

    Both index types expose ``search``/``search_projected``; the sharded
    one fans the batch out over the mesh and merges per-shard top-k, so the
    server loop is layout-agnostic. With a pruner attached, every batch is
    one fused dispatch (``search_projected``: projection + scale fold +
    scan); without one it falls back to plain ``search``.

    ``pipeline_depth`` >= 2 (default 3) runs the stager/completer pipeline
    with that many batches in flight; <= 1 is the synchronous loop. Every
    executed batch is logged as ``(size, t_dispatch, t_done)`` so both
    occupancy and worker-side throughput are reportable: ``worker_qps``
    (queries / busy-span wall time — the honest pipelined number, overlap
    counted once) and ``service_qps`` (queries / summed per-batch service
    time — matches the old sync metric, but double-counts overlapped
    seconds when pipelined).

    ``bucket_batches=True`` pads partial batches to the next bucket in
    {8, 16, 32, …, max_batch} instead of always ``max_batch`` — a handful
    of compiled shapes traded for up to 4x less pad compute at low load
    (call ``warmup()`` to pre-compile every bucket).

    ``swap_index`` installs a NEW index (a fresh ``SegmentedIndex`` after a
    live append or compaction) atomically *between* batches: the worker
    snapshots (index, projection) under a lock per dispatch, so every batch
    runs entirely against one segment set, and in-flight batches keep the
    old set's arrays alive until their replies post — no reply is dropped
    or computed against a half-swapped state.
    """

    _KEEP = object()   # swap_index sentinel: leave the projection alone

    def __init__(self, index: DenseIndex | ShardedDenseIndex,
                 pruner: StaticPruner | None,
                 k: int = 10, max_batch: int = 32,
                 pipeline_depth: int = 3,
                 bucket_batches: bool = False):
        self.index = index
        self.pruner = pruner
        self.k = k
        self.max_batch = max_batch
        self.bucket_batches = bucket_batches
        caps, c = [], min(8, max_batch)
        while c < max_batch:
            caps.append(c)
            c *= 2
        caps.append(max_batch)
        self._buckets = tuple(caps)
        self.pipeline_depth = max(1, pipeline_depth)
        self.batcher = BatchingQueue(max_batch=max_batch)
        # (size, t_dispatch, t_done) per executed batch; appended by the
        # completer, snapshotted by stats readers on other threads
        self.batch_log: list[tuple[int, float, float]] = []
        self._log_lock = threading.Lock()
        self._index_lock = threading.Lock()
        self.swap_count = 0
        self._proj = None
        if pruner is not None:
            W, mean = pruner.projection()
            self._proj = (jnp.asarray(W),
                          None if mean is None else jnp.asarray(mean))
        self._stop = threading.Event()
        self.error: BaseException | None = None   # first worker-thread crash
        # replies submitted with a deadline, swept by the completer: an
        # overdue queued request gets an explicit TimedOut payload instead
        # of parking its client forever behind a hung dispatch
        self._pending_dl: list[Reply] = []
        self._dl_lock = threading.Lock()
        if self.pipeline_depth >= 2:
            # bounded in-flight window. The semaphore gates batch ASSEMBLY,
            # not just dispatch: while every slot is busy, requests keep
            # accumulating in the batcher, so the next batch assembles full
            # instead of greedily draining the queue into padded fragments
            # (which burns compute on pad rows and sinks occupancy).
            self._slots = threading.Semaphore(self.pipeline_depth)
            self._inflight: queue.Queue = queue.Queue()
            self._inflight_n = 0
            self._inflight_lock = threading.Lock()
            self._threads = [
                threading.Thread(target=self._guard, args=(self._stage_loop,),
                                 daemon=True),
                threading.Thread(target=self._guard,
                                 args=(self._complete_loop,), daemon=True)]
        else:
            self._threads = [threading.Thread(target=self._guard,
                                              args=(self._loop,), daemon=True)]
        for t in self._threads:
            t.start()

    def _guard(self, loop):
        """Worker-thread crashes must be loud: record the exception, stop
        the server (so clients' reply timeouts fire instead of hanging
        forever), and unblock the sibling thread."""
        try:
            loop()
        except BaseException as e:   # noqa: BLE001 — survives for reporting
            import traceback
            self.error = e
            self._stop.set()
            self.batcher.kick()
            if self.pipeline_depth >= 2:
                # fail-fast dispatched-but-unposted batches too: their
                # replies would otherwise wait out their full timeout
                while True:
                    try:
                        it = self._inflight.get_nowait()
                    except queue.Empty:
                        break
                    if it is not None:
                        for r in it[2]:
                            r.resolve(e)
                self._inflight.put(None)   # release a blocked completer
            # fail-fast every queued request: clients get the exception
            # immediately instead of waiting out their reply timeout
            for _, reply in self.batcher.drain():
                reply.resolve(e)
            traceback.print_exc()

    def _bucket_for(self, b: int) -> int:
        if not self.bucket_batches:
            return self.max_batch
        for cap in self._buckets:
            if cap >= b:
                return cap
        return self.max_batch

    def _dispatch(self, vecs: np.ndarray):
        """Enqueue one batch's fused search; returns device arrays
        immediately (JAX async dispatch) — the caller decides when to
        block on the transfer back.

        Batches are zero-padded to a FIXED set of compiled shapes — always
        ``max_batch``, or the next bucket in {8, 16, …, max_batch} with
        ``bucket_batches`` — so a novel partial-batch size never
        jit-compiles a fresh full-index scan mid-serve (hundreds of ms of
        compile stampeding the worker exactly when load is ragged). Pad
        rows cost compute but are sliced off before reply; exact-search
        results are row-independent, so real rows are bit-identical to an
        unpadded dispatch.

        The (index, projection) pair is snapshotted under the swap lock:
        the whole batch runs against one consistent segment set even if
        ``swap_index`` lands mid-flight.
        """
        with self._index_lock:
            index, proj = self.index, self._proj
        b = len(vecs)
        cap = self._bucket_for(b)
        if b < cap:
            vecs = np.concatenate(
                [vecs, np.zeros((cap - b, vecs.shape[1]), vecs.dtype)])
        q = jnp.asarray(vecs)
        if proj is not None:
            W, mean = proj
            return index.search_projected(q, W, k=self.k, mean=mean)
        return index.search(q, k=self.k)

    def _post(self, scores, ids, replies, t0):
        try:
            scores = np.asarray(scores)   # blocks on this batch's D2H only
            ids = np.asarray(ids)         # (both BEFORE taking any lock)
        except BaseException as e:
            # a poisoned device result must fail ITS batch's clients, not
            # strand them: resolve in-hand replies before the crash
            # propagates to _guard
            t = time.perf_counter()
            for r in replies:
                r.resolve(e, t)
            raise
        t1 = time.perf_counter()
        with self._log_lock:
            self.batch_log.append((len(replies), t0, t1))
        for i, r in enumerate(replies):
            # first-writer-wins: an already-expired reply keeps its
            # TimedOut (and the single slot is never double-filled)
            r.resolve((scores[i], ids[i]), t1)

    # -- deadline expiry ----------------------------------------------------
    def _dl_poll(self) -> float:
        """Completer wait quantum: fine-grained while deadlines are
        pending, coarse (but bounded — a hung stager must not be able to
        park the sweep forever) when none are."""
        with self._dl_lock:
            pending = bool(self._pending_dl)
        return 0.05 if pending else 0.5

    def _expire_overdue(self) -> None:
        """Resolve every overdue pending reply with TimedOut. Replies are
        collected under the deadline lock but resolved OUTSIDE it — reply
        delivery never runs under a server lock."""
        now = time.perf_counter()
        with self._dl_lock:
            live = [r for r in self._pending_dl if not r.done]
            due = [r for r in live if r.deadline <= now]
            self._pending_dl = [r for r in live if r.deadline > now]
        for r in due:
            r.resolve(TimedOut(
                f"reply deadline exceeded ({now - r.deadline:.3f}s overdue) "
                f"— batch never posted"), now)

    def swap_index(self, index, pruner=_KEEP) -> None:
        """Atomically install a new index (segment set) for future batches.

        Runs between batches by construction: ``_dispatch`` snapshots
        (index, projection) under the same lock, in-flight batches hold
        references to the old arrays, and the completer drains them
        normally — accepted work is never dropped and no batch ever sees a
        half-swapped state. Pass ``pruner`` to atomically replace the
        query projection too (a refit changed ``W_m``); by default the
        existing projection is kept (appends/compaction never change it).
        """
        proj = None
        if pruner is not self._KEEP and pruner is not None:
            W, mean = pruner.projection()
            # device transfers stay OUTSIDE the lock: a dispatch snapshot
            # must never wait on an H2D copy
            proj = (jnp.asarray(W),
                    None if mean is None else jnp.asarray(mean))
        with self._index_lock:
            if pruner is self._KEEP:
                proj = self._proj
            self.index = index
            self._proj = proj
            self.swap_count += 1

    def warmup(self) -> None:
        """Compile every dispatch shape (each bucket) before taking load —
        without this, the first partial batch of each bucket size pays its
        compile mid-serve."""
        d = self._query_dim()
        caps = self._buckets if self.bucket_batches else (self.max_batch,)
        for cap in caps:
            jax.block_until_ready(
                self._dispatch(np.zeros((cap, d), np.float32)))

    # -- synchronous worker (pipeline_depth <= 1) ---------------------------
    def _loop(self):
        # deadline expiry here is opportunistic (between batches): with one
        # thread, a dispatch that hangs also hangs the sweep — prompt
        # in-hang expiry needs pipeline_depth >= 2 (completer-side sweep)
        while not (self._stop.is_set() and self.batcher.empty()):
            self._expire_overdue()
            item = self.batcher.next_batch(stop=self._stop,
                                           timeout=self._dl_poll())
            if item is None:
                continue
            vecs, replies = item
            t0 = time.perf_counter()
            scores, ids = self._dispatch_guarded(vecs, replies)
            self._post(scores, ids, replies, t0)

    def _dispatch_guarded(self, vecs, replies):
        """_dispatch, but a crash resolves the in-hand batch's replies with
        the exception before propagating to _guard — the batch being
        assembled is accepted work, and accepted work never silently
        strands its clients."""
        try:
            return self._dispatch(vecs)
        except BaseException as e:
            t = time.perf_counter()
            for r in replies:
                r.resolve(e, t)
            raise

    # -- pipelined worker (stager + completer) ------------------------------
    def _busy(self) -> bool:
        """True while earlier batches are still in flight (and we are not
        draining): the stager should then hold out for a full batch."""
        return self._inflight_n > 0 and not self._stop.is_set()

    def _stage_loop(self):
        while not ((self._stop.is_set() and self.batcher.empty())
                   or self.error is not None):
            if not self._slots.acquire(timeout=0.2):
                continue                           # re-check stop, try again
            item = self.batcher.next_batch(stop=self._stop,
                                           want_full=self._busy)
            if item is None:
                self._slots.release()
                continue
            vecs, replies = item
            t0 = time.perf_counter()
            # async — does not block
            scores, ids = self._dispatch_guarded(vecs, replies)
            with self._inflight_lock:
                self._inflight_n += 1
            self._inflight.put((scores, ids, replies, t0))
        self._inflight.put(None)                   # drain sentinel

    def _complete_loop(self):
        while True:
            try:
                item = self._inflight.get(timeout=self._dl_poll())
            except queue.Empty:
                # nothing posted within the quantum: sweep overdue
                # deadlines — this is what un-wedges clients of a HUNG
                # dispatch (the stager is parked inside the device call,
                # but their deadlines still fire here)
                self._expire_overdue()
                continue
            if item is None:
                return
            self._post(*item)
            self._expire_overdue()
            with self._inflight_lock:
                self._inflight_n -= 1
                idle = self._inflight_n == 0
            self._slots.release()
            if idle:
                self.batcher.kick()   # device drained: flush partial batches

    def _query_dim(self) -> int:
        """Expected query dimensionality, from a CONSISTENT (index, proj)
        snapshot: a concurrent ``swap_index(..., pruner=...)`` must not be
        observed half-applied (old projection, new index)."""
        with self._index_lock:
            index, proj = self.index, self._proj
        return proj[0].shape[0] if proj is not None else index.dim

    # -- client API ---------------------------------------------------------
    def submit(self, qvec: np.ndarray,
               deadline: float | None = None) -> "Reply":
        """Open-loop entry: enqueue a query, return its reply queue.

        The shape is validated here, synchronously: a malformed vector must
        fail its submitter, not poison a whole batch inside the worker.
        ``deadline`` (relative seconds) arms completer-side expiry: if the
        batch has not posted by then, the reply resolves to ``TimedOut``
        instead of parking its client behind a hung dispatch. Submitting to
        an already-crashed server raises immediately.
        """
        qvec = np.asarray(qvec)
        if self.error is not None:
            raise RuntimeError("server worker failed") from self.error
        want = self._query_dim()
        if qvec.shape != (want,):
            raise ValueError(f"query must have shape ({want},), "
                             f"got {qvec.shape}")
        abs_dl = (None if deadline is None
                  else time.perf_counter() + deadline)
        reply = self.batcher.submit(qvec, deadline=abs_dl)
        if abs_dl is not None:
            with self._dl_lock:
                self._pending_dl.append(reply)
        if self.error is not None:
            # the worker died between the check above and the enqueue: the
            # batcher drain already ran, so fail this reply directly
            reply.resolve(self.error)
        return reply

    def query(self, qvec: np.ndarray, timeout: float = 10.0,
              deadline: float | None = None):
        out = self.submit(qvec, deadline=deadline).get(timeout=timeout)
        if isinstance(out, TimedOut):
            raise out
        if isinstance(out, BaseException):
            raise RuntimeError("server worker failed") from out
        return out

    def reset_stats(self) -> None:
        """Drop the batch log (e.g. after a warmup query) so stats reflect
        steady state only."""
        with self._log_lock:
            self.batch_log.clear()

    def worker_stats(self) -> dict:
        """Occupancy + worker-side throughput from the executed batches."""
        with self._log_lock:
            log = list(self.batch_log)
        if not log:
            return dict(batches=0, mean_batch=0.0, occupancy=0.0,
                        worker_qps=0.0, service_qps=0.0)
        sizes = np.array([s for s, _, _ in log], dtype=np.float64)
        t0s = np.array([a for _, a, _ in log], dtype=np.float64)
        t1s = np.array([b for _, _, b in log], dtype=np.float64)
        span = float(t1s.max() - t0s.min())
        busy = float((t1s - t0s).sum())
        return dict(batches=len(log),
                    mean_batch=float(sizes.mean()),
                    occupancy=float(sizes.mean() / self.max_batch),
                    worker_qps=float(sizes.sum() / max(span, 1e-9)),
                    service_qps=float(sizes.sum() / max(busy, 1e-9)))

    def close(self):
        """Stop accepting work *after* draining: every already-submitted
        request is batched, executed, and replied to before the threads
        exit (pinned by tests/test_serve_pipeline.py)."""
        self._stop.set()
        self.batcher.kick()
        for t in self._threads:
            t.join(timeout=60.0)


def _serve_mesh(ndev: int, merge: str):
    """1-D mesh for the flat merge; the squarest 2-D factoring for the
    hierarchical one (a 1-long second axis degenerates to flat anyway)."""
    if merge == "hierarchical":
        a = next(d for d in range(int(ndev ** 0.5), 0, -1) if ndev % d == 0)
        if a > 1:
            return jax.make_mesh((a, ndev // a), ("row", "col"))
    return jax.make_mesh((ndev,), ("data",))


def _drive(server: RetrievalServer, Q: np.ndarray) -> tuple[float, np.ndarray]:
    """Issue every query in array order; (wall seconds, per-query latency s).

    Both sides of ``--compare-full`` go through this, so the query order,
    count, and batching pattern are identical — speedups are apples to
    apples. One untimed warmup query absorbs compilation; its batch is
    dropped from the worker log so occupancy/worker-qps reflect steady
    state, matching the client-side numbers.
    """
    server.query(Q[0])
    server.reset_stats()
    lat = np.empty(len(Q))
    t0 = time.perf_counter()
    for i in range(len(Q)):
        t = time.perf_counter()
        server.query(Q[i])
        lat[i] = time.perf_counter() - t
    return time.perf_counter() - t0, lat


def _lat_summary(lat_s: np.ndarray) -> dict:
    ms = np.asarray(lat_s) * 1e3
    return dict(p50_ms=float(np.percentile(ms, 50)),
                p95_ms=float(np.percentile(ms, 95)),
                p99_ms=float(np.percentile(ms, 99)),
                mean_ms=float(ms.mean()))


def _drive_open(server: RetrievalServer, Q: np.ndarray, rate: float,
                seed: int = 0, collect: bool = False,
                tolerate_errors: bool = False,
                deadline: float | None = None) -> dict:
    """Open-loop load: Poisson arrivals at ``rate`` qps, independent of
    completions.

    A closed loop (``_drive``) can never overrun the server — each query
    waits for the last — so it measures latency at trivial concurrency. An
    open loop submits on the arrival process a real fleet generates,
    exposing queueing and letting the pipeline actually fill. Latency is
    measured from each query's *scheduled* arrival (not the submit call),
    so submitter lag counts against the server, never for it (no
    coordinated omission), and ends at the reply's ``completed_at`` stamp
    posted by the completer — not at the collector's own clock — so
    out-of-FIFO completions (priorities, swaps) measure correctly. One
    warmup query absorbs compilation.

    Returns achieved/offered qps, p50/p95/p99 latency, and — with
    ``collect`` — the per-query (scores, ids) in submission order, used by
    the bench's sync-vs-pipelined bit-identity check.

    ``tolerate_errors`` is the fault-injection mode (the fleet soak): an
    exception payload (Shed, TimedOut, a replica crash) or a submit-time
    rejection counts in ``errors`` instead of failing the drive, and
    latency percentiles cover the successful replies only —
    ``n_ok``/``errors`` make the split explicit. ``deadline`` (relative
    seconds) is forwarded to every submit. Any target duck-typing
    ``submit``/``query``/``reset_stats`` (a ``Router``) drives the same
    way a single server does.
    """
    rng = np.random.default_rng(seed)
    server.query(Q[0])
    server.reset_stats()
    n = len(Q)
    gaps = rng.exponential(1.0 / rate, size=n)
    lat = np.full(n, np.nan)
    results: list = [None] * n if collect else None
    handoff: queue.Queue = queue.Queue()
    done = threading.Event()
    errors: list = []
    fails: list = []

    def collector():
        # per-reply timeout: a dead worker thread must fail this drive
        # loudly (CI would otherwise hang to its job timeout), not wedge it
        try:
            for _ in range(n):
                i, reply, t_arr = handoff.get()
                if isinstance(reply, BaseException):   # rejected at submit
                    fails.append((i, reply))
                    continue
                out = reply.get(timeout=120.0)
                if isinstance(out, BaseException):
                    if tolerate_errors:
                        fails.append((i, out))
                        continue
                    raise out
                t_done = getattr(reply, "completed_at", None)
                lat[i] = (t_done if t_done is not None
                          else time.perf_counter()) - t_arr
                if collect:
                    results[i] = out
        except BaseException as e:   # noqa: BLE001 — must reach the driver
            errors.append(e)
        finally:
            done.set()

    th = threading.Thread(target=collector, daemon=True)
    th.start()
    t_start = time.perf_counter()
    t_next = t_start
    for i in range(n):
        t_next += gaps[i]
        delay = t_next - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        try:
            reply = server.submit(Q[i], deadline=deadline) \
                if deadline is not None else server.submit(Q[i])
        except Exception as e:
            if not tolerate_errors:
                done.set()
                raise
            reply = e
        handoff.put((i, reply, t_next))
    done.wait()
    if errors:
        raise RuntimeError(
            "open-loop drive failed: a reply never arrived (worker thread "
            "dead?)") from errors[0]
    wall = time.perf_counter() - t_start
    ok = lat[~np.isnan(lat)]
    out = dict(offered_qps=float(rate), achieved_qps=float(n / wall),
               wall_s=float(wall), n=int(n), n_ok=int(ok.size),
               errors=len(fails),
               **_lat_summary(ok if ok.size else np.array([np.inf])))
    if collect:
        out["results"] = results
    return out


def _serve_fleet(args) -> None:
    """--fleet path: R replicas behind a Router, driven open-loop; with
    --fleet-kill, a kill/restart fault plan runs mid-drive and the
    droplessness/misroute invariants are reported."""
    import tempfile

    # deferred: repro.serving.fleet imports this module
    from repro.serving.fleet import FaultEvent, FaultPlan, ReplicaSet

    if args.load_index:
        store_path, ctx = args.load_index, None
        src_d = int(IndexStore.open(store_path).meta.get("source_dim",
                                                         args.dim))
        if src_d != args.dim:
            print(f"[serve] store was fit at d={src_d}; overriding --dim")
            args.dim = src_d
    else:
        ctx = None if args.save_index else tempfile.TemporaryDirectory()
        store_path = args.save_index or (ctx.name + "/fleet-store")
        print(f"[serve] building corpus n={args.n_docs} d={args.dim}")
        ds = make_dataset("tasb", n_docs=args.n_docs, d=args.dim,
                          query_sets=("dl19",))
        pruner = StaticPruner(cutoff=args.cutoff).fit(jnp.asarray(ds.docs))
        st = save_index(store_path, pruner.build_index(jnp.asarray(ds.docs)),
                        pruner=pruner)
        print(f"[serve] artifact: {store_path} "
              f"({st.nbytes/2**20:.1f} MiB, n={st.n})")
    ds = make_dataset("tasb", n_docs=256, d=args.dim, query_sets=("dl19",))
    Q = np.asarray(ds.queries["dl19"])
    Q = np.tile(Q, (max(1, args.queries // len(Q) + 1), 1))[:args.queries]

    rate = args.open_loop if args.open_loop > 0 else 200.0
    fleet = ReplicaSet(store_path, replicas=args.fleet, k=args.k,
                       max_batch=args.batch,
                       pipeline_depth=args.pipeline_depth,
                       backend=args.backend, probe_queries=Q[:16])
    try:
        print(f"[serve] fleet: {args.fleet} replicas, open loop @ "
              f"{rate:.0f} qps, {len(Q)} queries")
        if args.fleet_kill > 0:
            FaultPlan([FaultEvent(args.fleet_kill, "kill", "r1"),
                       FaultEvent(args.fleet_kill + 2.0, "restart", "r1")]
                      ).start(fleet)
            print(f"[serve] fault plan: kill r1 @ {args.fleet_kill:.1f}s, "
                  f"restart @ {args.fleet_kill + 2.0:.1f}s")
        res = _drive_open(fleet, Q, rate=rate, tolerate_errors=True,
                          deadline=2.0)
        stats = fleet.stats()
        health = fleet.health()
        print(f"[serve] fleet drive: {res['achieved_qps']:.1f} qps achieved "
              f"({res['n_ok']}/{res['n']} ok)  p50={res['p50_ms']:.2f}ms "
              f"p95={res['p95_ms']:.2f}ms p99={res['p99_ms']:.2f}ms")
        print(f"[serve] fleet accounting: accepted={stats['accepted']} "
              f"completed={stats['completed']} shed={stats['shed']} "
              f"timed_out={stats['timed_out']} failed={stats['failed']} "
              f"failovers={stats['failovers']} "
              f"lost_accepted={stats['lost_accepted']}")
        states = ", ".join(f"{name}={rep['state']}"
                           for name, rep in health["replicas"].items())
        print(f"[serve] fleet health: "
              f"{'ok' if health['ok'] else 'DEGRADED'} ({states})")
    finally:
        fleet.close()
        if ctx is not None:
            ctx.cleanup()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=50000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--cutoff", type=float, default=0.5)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--pipeline-depth", type=int, default=3,
                    help="max batches in flight (stager/completer overlap); "
                         "<=1 runs the legacy synchronous worker loop")
    ap.add_argument("--bucket-batches", action="store_true",
                    help="pad partial batches to the next bucket in "
                         "{8,16,...,max_batch} instead of always max_batch "
                         "(less pad compute at low load, a few more "
                         "compiles)")
    ap.add_argument("--live-append", type=float, default=0.0,
                    metavar="ROWS_PER_S",
                    help="serve through a SegmentedIndex and append "
                         "synthetic documents at this rate during the "
                         "drive — every append swaps a fresh segment set "
                         "into the running server (then compacts at the "
                         "end)")
    ap.add_argument("--delta-capacity", type=int, default=4096,
                    help="fixed padded capacity of each delta segment "
                         "(the compiled dispatch shape for live appends)")
    ap.add_argument("--open-loop", type=float, default=0.0, metavar="QPS",
                    help="additionally drive Poisson arrivals at QPS "
                         "(open loop: submissions never wait on replies) "
                         "and report p50/p95/p99 under that load")
    ap.add_argument("--compare-full", action="store_true")
    ap.add_argument("--sharded", action="store_true",
                    help="row-shard the index over a mesh of every device")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force an N-way host-platform mesh via XLA_FLAGS "
                         "(default: 4 when --sharded; no-op on non-CPU "
                         "platforms or once JAX is initialised)")
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp",
                    help="scan backend for the (per-shard) score+top-k")
    ap.add_argument("--merge", choices=("flat", "hierarchical"),
                    default="flat",
                    help="sharded candidate merge: one all-gather over "
                         "every device, or two stages over a factored mesh")
    ap.add_argument("--quantize-int8", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="serve through a PagedIndex: fixed-size pages "
                         "behind an indirection table — appends, "
                         "promotion, compaction and eviction are "
                         "page-pointer swaps (zero steady-state "
                         "recompiles), and the index may exceed device "
                         "memory (see --page-pool)")
    ap.add_argument("--page-rows", type=int, default=0, metavar="R",
                    help="rows per page (default: 256, or the artifact's "
                         "page geometry under --load-index)")
    ap.add_argument("--page-pool", type=int, default=0, metavar="P",
                    help="cap the device-resident page pool at P pages; "
                         "overflow pages stay host-side and stream "
                         "through the DMA pipeline on demand "
                         "(default: everything resident)")
    ap.add_argument("--cascade", default=None, metavar="M:N",
                    help="serve a two-resolution cascade: coarse scan over "
                         "the first M PCA dims (int8) keeps N*k candidates "
                         "per query, then one exact full-m rescore of the "
                         "shortlist (e.g. 64:8)")
    ap.add_argument("--fleet", type=int, default=0, metavar="R",
                    help="serve through a replicated fleet of R servers "
                         "behind a load-aware router (admission control, "
                         "retry-with-failover, health-gated maintenance) "
                         "instead of one bare server")
    ap.add_argument("--fleet-kill", type=float, default=0.0, metavar="SEC",
                    help="with --fleet: kill replica r1 SEC seconds into "
                         "the drive and restart it 2s later — prints the "
                         "droplessness/misroute accounting the chaos soak "
                         "asserts")
    ap.add_argument("--save-index", default=None, metavar="DIR",
                    help="persist the built artifact (PCA state + pruned "
                         "vectors + int8 scale) to DIR for later "
                         "--load-index restarts")
    ap.add_argument("--load-index", default=None, metavar="DIR",
                    help="serve from an on-disk artifact: skips the PCA "
                         "refit and index rebuild entirely (the paper's "
                         "offline/online split, made real)")
    args = ap.parse_args()
    if args.save_index and args.load_index:
        ap.error("--save-index and --load-index are mutually exclusive")
    if args.paged and args.sharded:
        ap.error("--paged does not compose with --sharded yet "
                 "(paged per-shard pools: see ROADMAP)")
    if args.paged and args.fleet > 0:
        ap.error("--paged does not compose with --fleet yet "
                 "(paged replicas load via the store auto-detect path)")
    page_rows = args.page_rows or 256
    pool_pages = args.page_pool or None
    cascade_mn = None
    if args.cascade:
        if args.sharded:
            ap.error("--cascade does not compose with --sharded yet "
                     "(sharded base rescore: see ROADMAP)")
        try:
            mc_s, nf_s = args.cascade.split(":")
            cascade_mn = (int(mc_s), int(nf_s))
        except ValueError:
            ap.error(f"--cascade wants M:N (e.g. 64:8), got {args.cascade!r}")
        if cascade_mn[0] < 1 or cascade_mn[1] < 1:
            ap.error("--cascade M and N must both be >= 1")

    force_host_device_count(args.host_devices or (4 if args.sharded else 0))

    if args.fleet > 0:
        if args.sharded or args.cascade or args.live_append > 0:
            ap.error("--fleet composes with the single-node flat index only "
                     "(sharded/cascade fleet replicas: see ROADMAP)")
        _serve_fleet(args)
        return

    if args.load_index:
        # peek at the artifact for the query dimensionality, synthesise the
        # query stream, then time the restart proper: open+validate, load,
        # first answered query — the same span as the perf sweep's
        # cold_start row (the peek costs one extra validate, ~ms)
        src_d = int(IndexStore.open(args.load_index).meta.get("source_dim",
                                                             args.dim))
        if src_d != args.dim:
            print(f"[serve] store was fit at d={src_d}; overriding --dim")
            args.dim = src_d
        # a tiny corpus is enough to synthesise the query stream — the
        # served docs come from the artifact, not from here
        ds = make_dataset("tasb", n_docs=256, d=args.dim,
                          query_sets=("dl19",))
        Q = np.asarray(ds.queries["dl19"])
        Q = np.tile(Q, (max(1, args.queries // len(Q) + 1), 1))[:args.queries]

        t_cold = time.perf_counter()
        store = IndexStore.open(args.load_index)
        pruner = store.load_pruner()
        if args.sharded:
            mesh = _serve_mesh(jax.device_count(), args.merge)
            index = ShardedDenseIndex.load(store, mesh,
                                           backend=args.backend,
                                           merge=args.merge)
            print(f"[serve] loaded sharded index: {index.n} x {index.dim} "
                  f"over mesh "
                  f"{dict(zip(mesh.axis_names, mesh.devices.shape))} "
                  f"({index.nbytes/2**20:.1f} MiB, backend={args.backend}, "
                  f"merge={args.merge})")
        elif cascade_mn:
            index = CascadeIndex.load(store, m_coarse=cascade_mn[0],
                                      n_factor=cascade_mn[1],
                                      backend=args.backend,
                                      segmented=args.live_append > 0,
                                      paged=args.paged,
                                      page_rows=args.page_rows or None,
                                      pool_pages=pool_pages,
                                      delta_capacity=args.delta_capacity)
            print(f"[serve] loaded cascade: {index.n} x {index.dim} "
                  f"(+ coarse m={index.m_coarse}, shortlist "
                  f"{index.n_factor}*k, {index.nbytes/2**20:.1f} MiB"
                  f"{', paged' if args.paged else ''})")
        elif args.paged or "paged" in store.manifest:
            from repro.core.paged import PagedIndex
            index = PagedIndex.load(store, backend=args.backend,
                                    page_rows=args.page_rows or None,
                                    pool_pages=pool_pages)
            stg = index.storage
            print(f"[serve] loaded paged index: {index.n} x {index.dim} "
                  f"({index.nbytes/2**20:.1f} MiB, {stg.n_slots} pages "
                  f"x {stg.page_rows} rows, {stg.n_host_pages} host-tier)")
        else:
            index = DenseIndex.load(store, backend=args.backend)
            print(f"[serve] loaded index: {index.n} x {index.dim} "
                  f"({index.nbytes/2**20:.1f} MiB, "
                  f"dtype={index.vectors.dtype})")
        server = RetrievalServer(index, pruner, k=args.k,
                                 max_batch=args.batch,
                                 pipeline_depth=args.pipeline_depth,
                                 bucket_batches=args.bucket_batches)
        server.query(Q[0])   # first answered query closes the cold start
        print(f"[serve] cold start (open store -> first query): "
              f"{(time.perf_counter() - t_cold)*1e3:.1f}ms")
        server.reset_stats()
    else:
        print(f"[serve] building corpus n={args.n_docs} d={args.dim}")
        ds = make_dataset("tasb", n_docs=args.n_docs, d=args.dim,
                          query_sets=("dl19",))
        D = jnp.asarray(ds.docs)
        Q = np.asarray(ds.queries["dl19"])
        Q = np.tile(Q, (max(1, args.queries // len(Q) + 1), 1))[:args.queries]

        pruner = StaticPruner(cutoff=args.cutoff).fit(D)
        pruned = pruner.prune_index(D)
        if args.sharded:
            ndev = jax.device_count()
            mesh = _serve_mesh(ndev, args.merge)
            index = ShardedDenseIndex.build(pruned, mesh,
                                            quantize_int8=args.quantize_int8,
                                            backend=args.backend,
                                            merge=args.merge)
            print(f"[serve] sharded index: {index.n} x {index.dim} over "
                  f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
                  f"({index.nbytes/2**20:.1f} MiB, backend={args.backend}, "
                  f"merge={args.merge})")
        elif cascade_mn:
            index = CascadeIndex.build(pruned, m_coarse=cascade_mn[0],
                                       n_factor=cascade_mn[1],
                                       quantize_int8=args.quantize_int8,
                                       backend=args.backend)
            if args.paged:
                index = index.paged(page_rows=page_rows,
                                    pool_pages=pool_pages,
                                    seal_rows=args.delta_capacity)
            print(f"[serve] cascade index: {index.n} x {index.dim} "
                  f"(+ coarse m={index.m_coarse} int8, shortlist "
                  f"{index.n_factor}*k, {index.nbytes/2**20:.1f} MiB"
                  f"{', paged' if args.paged else ''})")
        elif args.paged:
            from repro.core.paged import PagedIndex
            base = DenseIndex.build(pruned, quantize_int8=args.quantize_int8)
            index = PagedIndex.from_index(base, page_rows=page_rows,
                                          pool_pages=pool_pages,
                                          seal_rows=args.delta_capacity,
                                          backend=args.backend)
            stg = index.storage
            print(f"[serve] paged index: {index.n} x {index.dim} "
                  f"({index.nbytes/2**20:.1f} MiB, {stg.n_slots} pages "
                  f"x {stg.page_rows} rows, {stg.n_host_pages} host-tier)")
        else:
            index = DenseIndex.build(pruned, quantize_int8=args.quantize_int8,
                                     backend=args.backend)
            print(f"[serve] pruned index: {index.n} x {index.dim} "
                  f"({index.nbytes/2**20:.1f} MiB)")
        if args.save_index:
            st = save_index(args.save_index, index, pruner=pruner)
            print(f"[serve] saved artifact: {args.save_index} "
                  f"({st.nbytes/2**20:.1f} MiB on disk, n={st.n})")

        server = RetrievalServer(index, pruner, k=args.k, max_batch=args.batch,
                                 pipeline_depth=args.pipeline_depth,
                                 bucket_batches=args.bucket_batches)

    updater = None
    cascade_app = None
    append_stop = threading.Event()
    appender = None
    if args.live_append > 0 and cascade_mn:
        # CascadeIndex is copy-on-write: append grows BOTH resolutions and
        # swap_index installs the consistent pair atomically. IndexUpdater
        # is SegmentedIndex-specific, so the cascade drives the same
        # swap-between-batches discipline directly; only this thread ever
        # rebinds the local, so no extra lock is needed.
        from repro.core import SegmentedIndex
        if not (isinstance(index.full, SegmentedIndex)
                or hasattr(index.full, "storage")):
            index = index.segmented(delta_capacity=args.delta_capacity)
        server.swap_index(index)
        rng_app = np.random.default_rng(123)
        app_block = 64
        cascade_app = {"rows": 0, "index": index}

        def _appender():
            cas = cascade_app["index"]
            while not append_stop.is_set():
                t0 = time.perf_counter()
                block = jnp.asarray(
                    rng_app.standard_normal((app_block, args.dim))
                    .astype(np.float32))
                cas = cas.append(pruner.prune_index(block))
                server.swap_index(cas)
                cascade_app["rows"] += app_block
                cascade_app["index"] = cas
                delay = (app_block / args.live_append
                         - (time.perf_counter() - t0))
                if delay > 0:
                    append_stop.wait(delay)

        appender = threading.Thread(target=_appender, daemon=True)
        print(f"[serve] live-append (cascade): {args.live_append:.0f} "
              f"rows/s (blocks of {app_block}, delta capacity "
              f"{args.delta_capacity})")
        appender.start()
    elif args.live_append > 0:
        from repro.core import SegmentedIndex
        from repro.core.maintenance import IndexUpdater
        if hasattr(index, "storage"):
            # already paged: appends/compaction are page-pointer swaps on
            # the same object — no segmented wrapper needed
            seg = index
        else:
            seg = SegmentedIndex.from_index(
                index, delta_capacity=args.delta_capacity)
        server.swap_index(seg)
        updater = IndexUpdater(pruner=pruner, index=seg, server=server,
                               delta_capacity=args.delta_capacity)
        rng_app = np.random.default_rng(123)
        app_block = 64

        def _appender():
            while not append_stop.is_set():
                t0 = time.perf_counter()
                updater.add_documents(jnp.asarray(
                    rng_app.standard_normal((app_block, args.dim))
                    .astype(np.float32)))
                delay = (app_block / args.live_append
                         - (time.perf_counter() - t0))
                if delay > 0:
                    append_stop.wait(delay)

        appender = threading.Thread(target=_appender, daemon=True)
        print(f"[serve] live-append: {args.live_append:.0f} rows/s "
              f"(blocks of {app_block}, delta capacity "
              f"{args.delta_capacity})")
        appender.start()

    if args.bucket_batches:
        # pre-compile every bucket shape: without this the first partial
        # batch of each size pays its compile mid-drive — the exact
        # stampede bucketing exists to avoid
        server.warmup()

    wall, lat = _drive(server, Q)
    stats = server.worker_stats()
    lat_ms = lat * 1e3
    mode = ("pipelined" if args.pipeline_depth >= 2 else "sync")
    print(f"[serve] pruned ({mode}): {args.queries / wall:.1f} qps  "
          f"p50={np.percentile(lat_ms, 50):.2f}ms "
          f"p99={np.percentile(lat_ms, 99):.2f}ms")
    print(f"[serve] worker: {stats['worker_qps']:.1f} qps span "
          f"({stats['service_qps']:.1f} qps service) over "
          f"{stats['batches']} batches, mean batch "
          f"{stats['mean_batch']:.1f}/{args.batch} "
          f"({stats['occupancy']*100:.0f}% occupancy)")

    if args.open_loop > 0:
        res = _drive_open(server, Q, rate=args.open_loop)
        ostats = server.worker_stats()
        print(f"[serve] open-loop @ {args.open_loop:.0f} qps offered: "
              f"{res['achieved_qps']:.1f} qps achieved  "
              f"p50={res['p50_ms']:.2f}ms p95={res['p95_ms']:.2f}ms "
              f"p99={res['p99_ms']:.2f}ms  "
              f"worker={ostats['worker_qps']:.1f} qps "
              f"({ostats['occupancy']*100:.0f}% occupancy)")

    def _delta_units(idx) -> str:
        if hasattr(idx, "storage"):
            n_ext = sum(1 for e in idx.storage.extents if e.kind == "delta")
            return f"{idx.storage.delta_pages} delta page(s), {n_ext} extent(s)"
        return f"{len(idx.deltas)} delta segment(s)"

    if cascade_app is not None:
        append_stop.set()
        appender.join(timeout=30.0)
        cas = cascade_app["index"]
        print(f"[serve] live-append (cascade): +{cascade_app['rows']} rows "
              f"in {_delta_units(cas.full)} per resolution, "
              f"{server.swap_count} atomic swaps; index now {cas.n} rows "
              f"(both resolutions)")
    if updater is not None:
        append_stop.set()
        appender.join(timeout=30.0)
        print(f"[serve] live-append: +{updater.appended_rows} rows in "
              f"{_delta_units(updater.index)}, "
              f"{server.swap_count} atomic swaps; index now "
              f"{updater.index.n} rows")
        t0 = time.perf_counter()
        updater.compact()
        dt_ms = (time.perf_counter() - t0) * 1e3
        lc = updater.last_compaction or {}
        if "pages_moved" in lc:
            print(f"[serve] compaction (paged): {lc['pages_moved']} pages "
                  f"moved, {lc['pages_freed']} freed, {lc['pages_host']} "
                  f"host-tier, in {dt_ms:.0f}ms — pointer swaps, no "
                  f"rebuild; server swapped mid-serve "
                  f"(swap #{server.swap_count})")
        else:
            print(f"[serve] compaction: base+deltas -> one fresh base "
                  f"({updater.index.n} rows, fresh scale) in "
                  f"{dt_ms:.0f}ms; server swapped "
                  f"mid-serve (swap #{server.swap_count})")
    server.close()

    if args.compare_full and args.load_index:
        print("[serve] --compare-full needs the raw corpus; skipped under "
              "--load-index")
    elif args.compare_full:
        full = DenseIndex.build(D)
        server2 = RetrievalServer(full, None, k=args.k, max_batch=args.batch,
                                  pipeline_depth=args.pipeline_depth)
        wall_full, _ = _drive(server2, Q)   # identical query order/batching
        server2.close()
        print(f"[serve] full:   {args.queries / wall_full:.1f} qps  "
              f"speedup={wall_full / wall:.2f}x "
              f"(O(d/m) predicts {args.dim / pruner.kept_dims:.2f}x)")


if __name__ == "__main__":
    main()
