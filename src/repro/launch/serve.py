"""Retrieval serving driver: batched queries against a PCA-pruned index.

The paper's online path, end to end:
  1. load the offline artefacts (PCA transform W_m + pruned index D̂)
  2. batch incoming queries (micro-batching queue with a latency deadline)
  3. q̂ = W_mᵀ q  (the only added per-query cost: O(dm))
  4. fused score+top-k scan over the (sharded) index
  5. return doc ids + scores

``--compare-full`` serves the unpruned index side by side and reports the
measured speedup vs the O(d/m) prediction.

``--sharded`` row-shards the pruned index over a mesh of every visible
device and serves through ``ShardedDenseIndex`` (local fused scan + tiny
global top-k merge). On a CPU-only host, ``--host-devices N`` forces an
N-way mesh via ``XLA_FLAGS=--xla_force_host_platform_device_count`` —
the same code path a TPU pod takes, minus the speed. ``--backend pallas``
selects the fused score-and-select kernel for the (per-shard) scan.
``--merge hierarchical`` factors the device count into a 2-D mesh and
merges per-shard candidates in two all-gather stages (k·(a+b) candidates
per device instead of k·a·b).

``--save-index DIR`` persists the offline artifact (PCA state + pruned
vectors + int8 scale) through ``repro.core.store``; ``--load-index DIR``
serves from it — no PCA refit, no index rebuild, and the index is
host-streamed onto the device(s) (per-shard when ``--sharded``). The
cold-start time (open store -> first answered query) is printed.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --n-docs 50000 --dim 256 \
      --cutoff 0.5 --queries 256 --batch 32
  PYTHONPATH=src python -m repro.launch.serve --sharded --host-devices 4 \
      --backend pallas --merge hierarchical
  PYTHONPATH=src python -m repro.launch.serve --n-docs 50000 \
      --quantize-int8 --save-index /tmp/idx
  PYTHONPATH=src python -m repro.launch.serve --load-index /tmp/idx --sharded
"""
from __future__ import annotations

import argparse
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex, IndexStore, ShardedDenseIndex, StaticPruner
from repro.core.store import save_index
from repro.data.synthetic import make_dataset
from repro.util import force_host_device_count


class BatchingQueue:
    """Micro-batching: collect up to ``max_batch`` requests or flush at the
    latency deadline — the standard online-serving pattern."""

    def __init__(self, max_batch: int = 32, deadline_ms: float = 2.0):
        self.q: queue.Queue = queue.Queue()
        self.max_batch = max_batch
        self.deadline = deadline_ms / 1e3

    def submit(self, qvec: np.ndarray) -> "queue.Queue":
        reply: queue.Queue = queue.Queue(maxsize=1)
        self.q.put((qvec, reply))
        return reply

    def next_batch(self) -> tuple[np.ndarray, list] | None:
        try:
            first = self.q.get(timeout=0.5)
        except queue.Empty:
            return None
        items = [first]
        t0 = time.time()
        while len(items) < self.max_batch and (time.time() - t0) < self.deadline:
            try:
                items.append(self.q.get_nowait())
            except queue.Empty:
                time.sleep(0.0002)
        vecs = np.stack([x[0] for x in items])
        replies = [x[1] for x in items]
        return vecs, replies


class RetrievalServer:
    """Batched query server over a DenseIndex or ShardedDenseIndex.

    Both index types expose ``search(q, k) -> (scores, ids)``; the sharded
    one fans the batch out over the mesh and merges per-shard top-k, so the
    server loop is layout-agnostic.

    The worker loop records every executed batch (size, service seconds) so
    achieved batch occupancy and worker-side qps — queries / time the model
    actually ran, excluding queue idle — are reportable next to the
    client-side numbers.
    """

    def __init__(self, index: DenseIndex | ShardedDenseIndex,
                 pruner: StaticPruner | None,
                 k: int = 10, max_batch: int = 32):
        self.index = index
        self.pruner = pruner
        self.k = k
        self.max_batch = max_batch
        self.batcher = BatchingQueue(max_batch=max_batch)
        self.batch_log: list[tuple[int, float]] = []   # (size, service_s)
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    def _loop(self):
        while not self._stop.is_set():
            item = self.batcher.next_batch()
            if item is None:
                continue
            vecs, replies = item
            t0 = time.perf_counter()
            q = jnp.asarray(vecs)
            if self.pruner is not None:
                q = self.pruner.transform_queries(q)
            scores, ids = self.index.search(q, k=self.k)
            scores = np.asarray(scores)
            ids = np.asarray(ids)
            self.batch_log.append((len(replies), time.perf_counter() - t0))
            for i, r in enumerate(replies):
                r.put((scores[i], ids[i]))

    def query(self, qvec: np.ndarray, timeout: float = 10.0):
        return self.batcher.submit(qvec).get(timeout=timeout)

    def worker_stats(self) -> dict:
        """Achieved occupancy + worker-side qps from the executed batches."""
        if not self.batch_log:
            return dict(batches=0, mean_batch=0.0, occupancy=0.0,
                        worker_qps=0.0)
        sizes = np.array([s for s, _ in self.batch_log], dtype=np.float64)
        secs = np.array([t for _, t in self.batch_log], dtype=np.float64)
        return dict(batches=len(self.batch_log),
                    mean_batch=float(sizes.mean()),
                    occupancy=float(sizes.mean() / self.max_batch),
                    worker_qps=float(sizes.sum() / max(secs.sum(), 1e-9)))

    def close(self):
        self._stop.set()
        self._worker.join(timeout=2.0)


def _serve_mesh(ndev: int, merge: str):
    """1-D mesh for the flat merge; the squarest 2-D factoring for the
    hierarchical one (a 1-long second axis degenerates to flat anyway)."""
    if merge == "hierarchical":
        a = next(d for d in range(int(ndev ** 0.5), 0, -1) if ndev % d == 0)
        if a > 1:
            return jax.make_mesh((a, ndev // a), ("row", "col"))
    return jax.make_mesh((ndev,), ("data",))


def _drive(server: RetrievalServer, Q: np.ndarray) -> tuple[float, np.ndarray]:
    """Issue every query in array order; (wall seconds, per-query latency s).

    Both sides of ``--compare-full`` go through this, so the query order,
    count, and batching pattern are identical — speedups are apples to
    apples. One untimed warmup query absorbs compilation; its batch is
    dropped from the worker log so occupancy/worker-qps reflect steady
    state, matching the client-side numbers.
    """
    server.query(Q[0])
    server.batch_log.clear()
    lat = np.empty(len(Q))
    t0 = time.perf_counter()
    for i in range(len(Q)):
        t = time.perf_counter()
        server.query(Q[i])
        lat[i] = time.perf_counter() - t
    return time.perf_counter() - t0, lat


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=50000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--cutoff", type=float, default=0.5)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--compare-full", action="store_true")
    ap.add_argument("--sharded", action="store_true",
                    help="row-shard the index over a mesh of every device")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force an N-way host-platform mesh via XLA_FLAGS "
                         "(default: 4 when --sharded; no-op on non-CPU "
                         "platforms or once JAX is initialised)")
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp",
                    help="scan backend for the (per-shard) score+top-k")
    ap.add_argument("--merge", choices=("flat", "hierarchical"),
                    default="flat",
                    help="sharded candidate merge: one all-gather over "
                         "every device, or two stages over a factored mesh")
    ap.add_argument("--quantize-int8", action="store_true")
    ap.add_argument("--save-index", default=None, metavar="DIR",
                    help="persist the built artifact (PCA state + pruned "
                         "vectors + int8 scale) to DIR for later "
                         "--load-index restarts")
    ap.add_argument("--load-index", default=None, metavar="DIR",
                    help="serve from an on-disk artifact: skips the PCA "
                         "refit and index rebuild entirely (the paper's "
                         "offline/online split, made real)")
    args = ap.parse_args()
    if args.save_index and args.load_index:
        ap.error("--save-index and --load-index are mutually exclusive")

    force_host_device_count(args.host_devices or (4 if args.sharded else 0))

    if args.load_index:
        # peek at the artifact for the query dimensionality, synthesise the
        # query stream, then time the restart proper: open+validate, load,
        # first answered query — the same span as the perf sweep's
        # cold_start row (the peek costs one extra validate, ~ms)
        src_d = int(IndexStore.open(args.load_index).meta.get("source_dim",
                                                             args.dim))
        if src_d != args.dim:
            print(f"[serve] store was fit at d={src_d}; overriding --dim")
            args.dim = src_d
        # a tiny corpus is enough to synthesise the query stream — the
        # served docs come from the artifact, not from here
        ds = make_dataset("tasb", n_docs=256, d=args.dim,
                          query_sets=("dl19",))
        Q = np.asarray(ds.queries["dl19"])
        Q = np.tile(Q, (max(1, args.queries // len(Q) + 1), 1))[:args.queries]

        t_cold = time.perf_counter()
        store = IndexStore.open(args.load_index)
        pruner = store.load_pruner()
        if args.sharded:
            mesh = _serve_mesh(jax.device_count(), args.merge)
            index = ShardedDenseIndex.load(store, mesh,
                                           backend=args.backend,
                                           merge=args.merge)
            print(f"[serve] loaded sharded index: {index.n} x {index.dim} "
                  f"over mesh "
                  f"{dict(zip(mesh.axis_names, mesh.devices.shape))} "
                  f"({index.nbytes/2**20:.1f} MiB, backend={args.backend}, "
                  f"merge={args.merge})")
        else:
            index = DenseIndex.load(store, backend=args.backend)
            print(f"[serve] loaded index: {index.n} x {index.dim} "
                  f"({index.nbytes/2**20:.1f} MiB, "
                  f"dtype={index.vectors.dtype})")
        server = RetrievalServer(index, pruner, k=args.k,
                                 max_batch=args.batch)
        server.query(Q[0])   # first answered query closes the cold start
        print(f"[serve] cold start (open store -> first query): "
              f"{(time.perf_counter() - t_cold)*1e3:.1f}ms")
        server.batch_log.clear()
    else:
        print(f"[serve] building corpus n={args.n_docs} d={args.dim}")
        ds = make_dataset("tasb", n_docs=args.n_docs, d=args.dim,
                          query_sets=("dl19",))
        D = jnp.asarray(ds.docs)
        Q = np.asarray(ds.queries["dl19"])
        Q = np.tile(Q, (max(1, args.queries // len(Q) + 1), 1))[:args.queries]

        pruner = StaticPruner(cutoff=args.cutoff).fit(D)
        pruned = pruner.prune_index(D)
        if args.sharded:
            ndev = jax.device_count()
            mesh = _serve_mesh(ndev, args.merge)
            index = ShardedDenseIndex.build(pruned, mesh,
                                            quantize_int8=args.quantize_int8,
                                            backend=args.backend,
                                            merge=args.merge)
            print(f"[serve] sharded index: {index.n} x {index.dim} over "
                  f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
                  f"({index.nbytes/2**20:.1f} MiB, backend={args.backend}, "
                  f"merge={args.merge})")
        else:
            index = DenseIndex.build(pruned, quantize_int8=args.quantize_int8,
                                     backend=args.backend)
            print(f"[serve] pruned index: {index.n} x {index.dim} "
                  f"({index.nbytes/2**20:.1f} MiB)")
        if args.save_index:
            st = save_index(args.save_index, index, pruner=pruner)
            print(f"[serve] saved artifact: {args.save_index} "
                  f"({st.nbytes/2**20:.1f} MiB on disk, n={st.n})")

        server = RetrievalServer(index, pruner, k=args.k, max_batch=args.batch)
    wall, lat = _drive(server, Q)
    stats = server.worker_stats()
    server.close()
    lat_ms = lat * 1e3
    print(f"[serve] pruned: {args.queries / wall:.1f} qps  "
          f"p50={np.percentile(lat_ms, 50):.2f}ms "
          f"p99={np.percentile(lat_ms, 99):.2f}ms")
    print(f"[serve] worker: {stats['worker_qps']:.1f} qps over "
          f"{stats['batches']} batches, mean batch "
          f"{stats['mean_batch']:.1f}/{args.batch} "
          f"({stats['occupancy']*100:.0f}% occupancy)")

    if args.compare_full and args.load_index:
        print("[serve] --compare-full needs the raw corpus; skipped under "
              "--load-index")
    elif args.compare_full:
        full = DenseIndex.build(D)
        server2 = RetrievalServer(full, None, k=args.k, max_batch=args.batch)
        wall_full, _ = _drive(server2, Q)   # identical query order/batching
        server2.close()
        print(f"[serve] full:   {args.queries / wall_full:.1f} qps  "
              f"speedup={wall_full / wall:.2f}x "
              f"(O(d/m) predicts {args.dim / pruner.kept_dims:.2f}x)")


if __name__ == "__main__":
    main()
