import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimb driver — named variants for the three chosen cells.

Each variant is a (hypothesis, change) pair; the driver lowers+compiles it,
extracts the three roofline terms, and appends the result to
``experiments/hillclimb/<cell>.json`` so EXPERIMENTS.md §Perf can show the
full hypothesis → change → before → after → verdict log.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell tt_retrieval
  PYTHONPATH=src python -m repro.launch.hillclimb --cell arctic_train
  PYTHONPATH=src python -m repro.launch.hillclimb --cell dlrm_train
"""
import argparse
import dataclasses
import json
import time

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def measure(bundle, mesh) -> dict:
    from repro.launch.flops import hlo_collectives, jaxpr_cost
    t0 = time.time()
    with mesh:
        compiled = bundle.lower().compile()
        acc = jaxpr_cost(bundle.fn, *bundle.args)
    hlo = compiled.as_text()
    coll = hlo_collectives(hlo)
    mem = compiled.memory_analysis()
    chips = int(np.prod(mesh.devices.shape))
    flops = acc["flops"]
    mem_bytes = bundle.meta.get("analytic_bytes", 0)
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = mem_bytes / (chips * HBM_BW)
    t_coll = coll["total_bytes"] / LINK_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    dom = max(terms, key=terms.get)
    model_flops = bundle.meta.get("model_flops") or 0
    step = max(terms.values())
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom, "step_time_s": step,
        "roofline_fraction": (model_flops / step) / (chips * PEAK_FLOPS)
        if step else 0.0,
        "collective_bytes_dev": coll["total_bytes"],
        "temp_gib": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
        "global_flops": flops, "model_flops": model_flops,
        "compile_s": round(time.time() - t0, 1),
    }


def tt_retrieval_variants():
    """Paper cell: 1 query vs 1M candidates. Dominant term = index stream."""
    from repro.configs.registry import get_arch
    spec = get_arch("two-tower-retrieval")
    cell = spec.cell("retrieval_cand")

    def variant(name, hypothesis, **dims):
        c = dataclasses.replace(cell, dims={**cell.dims, **dims})
        return name, hypothesis, spec, c

    return [
        variant("baseline_f32_256",
                "index stream C*256*4B dominates; collective (top-k merge) "
                "is ~200KB and secondary"),
        variant("pca50_f32_128",
                "PAPER: m=d/2 halves streamed bytes -> memory term /2; "
                "quality cost <5% nDCG (benchmarks Table 1)",
                index_dim=128),
        variant("pca50_int8_128",
                "BEYOND PAPER: int8 index on the rotated basis -> bytes /4 "
                "again (8x total); scale folds into q-hat so scan kernel "
                "is unchanged; expect collective term to become dominant",
                index_dim=128, int8=1),
        variant("pca75_int8_64",
                "BEYOND PAPER: 75% cutoff (paper: robust for low-rank "
                "encoders) + int8 -> 16x fewer bytes than baseline",
                index_dim=64, int8=1),
        variant("pca50_int8_hier_merge",
                "after compression the flat 256-shard top-k all-gather "
                "(205KB/dev) dominates: two-stage merge (model axis, then "
                "dp) cuts gather volume to (16+16)*k*8B = 25.6KB -> ~8x "
                "less collective",
                index_dim=128, int8=1, hier_merge=1),
        variant("pca75_int8_hier_merge",
                "compose 75% PCA + int8 + hierarchical merge: all three "
                "terms now within ~2x of each other (balanced design)",
                index_dim=64, int8=1, hier_merge=1),
        variant("pca50_int8_live_delta",
                "LIVE INDEX: sharded immutable base + one replicated open "
                "delta (8k rows, own scale, traced live count) merged via "
                "merge_segment_topk — the delta scan is 8k*m extra streamed "
                "bytes (<1% of the base) and the merge adds one tiny "
                "replicated top-k: live appends should be ~free at serve "
                "time",
                index_dim=128, int8=1, delta_rows=8192),
    ]


def arctic_train_variants():
    """Most collective-bound cell: FSDP weight re-gathers x microbatches."""
    from repro.configs.registry import get_arch
    spec = get_arch("arctic-480b")
    cell = spec.cell("train_4k")

    def variant(name, hypothesis, **cfg_over):
        s = dataclasses.replace(spec, cfg=dataclasses.replace(spec.cfg,
                                                              **cfg_over))
        return name, hypothesis, s, cell

    return [
        variant("baseline_mb16",
                "FSDP gathers weights per layer per microbatch x3 passes "
                "(fwd/bwd/remat): collective ~ 16 mb x 35 L x ~1.6GB"),
        variant("mb8",
                "halve microbatches -> FSDP re-gather bytes /2; activation "
                "memory x2 (2->4 GiB, still under HBM)",
                microbatch=8),
        variant("mb4",
                "quarter microbatches -> collective /4 vs baseline; "
                "activations x4 — check HBM headroom",
                microbatch=4),
        variant("mb4_group1024",
                "larger MoE dispatch groups cut all-to-all count per layer "
                "(same bytes, fewer launches); dispatch transient x2",
                microbatch=4, moe_group_size=1024),
        variant("cf1.0",
                "mb count refuted as the lever (collective is mb-invariant "
                "=> dominated by EP-side expert_in gathers over the "
                "FSDP-sharded ff dim). Shrink the gathered buffer directly: "
                "capacity_factor 1.25 -> 1.0 cuts C=10 -> 8 per group",
                capacity_factor=1.0),
        variant("cf1.0_mb8",
                "compose the capacity cut with mb8 (mb8 still halves the "
                "activation-side TP all-reduces even if expert gathers are "
                "invariant)",
                capacity_factor=1.0, microbatch=8),
        variant("moe_dp_d_model",
                "STRUCTURAL: FSDP-shard expert d_model instead of ff. The "
                "expert GEMMs then contract/produce the dp-sharded dim, so "
                "cross-dp traffic becomes (E_loc,G_loc,C,ff) partial-sum "
                "psums + (…,d) gathers ≈ 15-20MB/layer/pass instead of "
                "gathering 294MB dispatched activations",
                capacity_factor=1.0, moe_dp_dim="d_model"),
        variant("moe_dp_d_model_mb8",
                "compose structural fix with mb8 to also halve the "
                "remaining activation-side TP collectives",
                capacity_factor=1.0, moe_dp_dim="d_model", microbatch=8),
    ]


def dlrm_train_variants():
    """Worst-fraction family cell: dense-optimizer traffic + lookup pattern."""
    from repro.configs.registry import get_arch
    spec = get_arch("dlrm-mlperf")
    cell = spec.cell("train_batch")
    out = [("baseline_adamw_dense",
            "dense AdamW on 24B table params: optimizer RW ~386GB/step "
            "dominates memory term; XLA gather/scatter on FSDP tables "
            "drives collective",
            spec, cell)]
    rw = dataclasses.replace(spec, optimizer="rowwise")
    out.append(("rowwise_sparse",
                "gather rows OUTSIDE autodiff + rowwise AdaGrad: dense "
                "table grads never exist; optimizer traffic O(B*F*E) "
                "-> memory term ~/100; table scatter in place (donated)",
                rw, cell))
    rw16 = dataclasses.replace(
        rw, cfg=dataclasses.replace(rw.cfg, param_dtype="bfloat16"))
    out.append(("rowwise_bf16_tables",
                "XLA's sharded-gather strategy replicates row outputs at "
                "global batch (26 x 832MiB); bf16 tables halve every row "
                "byte moved (industry-standard fp16/bf16 embeddings)",
                rw16, cell))
    return out


def smollm_train_variants():
    """Bonus cell: the over-parallelisation finding made concrete."""
    from repro.configs.registry import get_arch
    spec = get_arch("smollm-135m")
    cell = spec.cell("train_4k")

    def variant(name, hypothesis, **cfg_over):
        s = dataclasses.replace(spec, cfg=dataclasses.replace(spec.cfg,
                                                              **cfg_over))
        return name, hypothesis, s, cell

    return [
        variant("baseline_tp16_fsdp",
                "TP16 per-layer all-reduces x remat x microbatches cost "
                "~21x the compute for a 135M model"),
        variant("dp_only",
                "replicate params (540MB fp32 fits trivially), batch-only "
                "sharding: collective collapses to the one grad all-reduce "
                "(~0.5GB/dev) => compute-bound, ~20x faster step",
                parallelism="dp_only"),
        variant("dp_only_mb1",
                "microbatching exists only for memory; DP-only activations "
                "are tiny, so drop it and save the grad-accum passes",
                parallelism="dp_only", microbatch=1),
    ]


CELLS = {
    "tt_retrieval": tt_retrieval_variants,
    "arctic_train": arctic_train_variants,
    "dlrm_train": dlrm_train_variants,
    "smollm_train": smollm_train_variants,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--only", default=None, help="variant name filter")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()

    from repro.configs.steps import BUNDLE_BUILDERS
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.cell}_{args.mesh}.json")
    log = []
    if os.path.exists(path):
        with open(path) as f:
            log = json.load(f)
    done = {e["variant"] for e in log}

    for name, hypothesis, spec, cell in CELLS[args.cell]():
        if args.only and args.only != name:
            continue
        if name in done:
            print(f"skip {name} (already measured)")
            continue
        print(f"== {name}: {hypothesis[:70]}")
        try:
            bundle = BUNDLE_BUILDERS[spec.family](spec, cell, mesh)
            m = measure(bundle, mesh)
            m.update(variant=name, hypothesis=hypothesis, status="ok")
        except Exception as e:
            import traceback
            m = dict(variant=name, hypothesis=hypothesis, status="error",
                     error=f"{type(e).__name__}: {e}",
                     traceback=traceback.format_exc()[-2000:])
        log.append(m)
        with open(path, "w") as f:
            json.dump(log, f, indent=1)
        if m["status"] == "ok":
            print(f"   compute={m['t_compute_s']:.3e}s memory={m['t_memory_s']:.3e}s "
                  f"collective={m['t_collective_s']:.3e}s dom={m['dominant']} "
                  f"step={m['step_time_s']:.3e}s temp={m['temp_gib']:.1f}GiB")
        else:
            print(f"   ERROR {m['error'][:120]}")


if __name__ == "__main__":
    main()
