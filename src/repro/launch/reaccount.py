import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Refresh the jaxpr-based accounting fields of existing dry-run artifacts
without recompiling (tracing only — seconds per cell instead of minutes).

  PYTHONPATH=src python -m repro.launch.reaccount [--dir experiments/dryrun]
"""
import argparse
import glob
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()

    import jax
    from repro.configs.registry import make_step_bundle
    from repro.launch.flops import jaxpr_cost
    from repro.launch.mesh import make_production_mesh

    meshes = {"pod": make_production_mesh(),
              "multipod": make_production_mesh(multi_pod=True)}

    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        mesh = meshes[rec["mesh"]]
        try:
            bundle = make_step_bundle(rec["arch"], rec["shape"], mesh)
            with mesh:
                acc = jaxpr_cost(bundle.fn, *bundle.args)
            rec["accounting"] = {"global_flops": float(acc["flops"]),
                                 "global_bytes": float(acc["bytes"])}
            rec["meta"] = {k: (int(v) if isinstance(v, int) else v)
                           for k, v in bundle.meta.items()}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"ok   {rec['arch']:24s} {rec['shape']:14s} {rec['mesh']:8s} "
                  f"flops={acc['flops']:.3e}")
        except Exception as e:
            print(f"FAIL {path}: {e}")


if __name__ == "__main__":
    main()
