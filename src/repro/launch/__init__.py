"""Launch layer: production meshes, AOT dry-run, training/serving drivers."""
