"""Serve a PCA-pruned index with batched concurrent requests.

Thin wrapper over the production driver (`repro.launch.serve`) showing the
public API: offline artefacts -> batching server -> concurrent clients.

  PYTHONPATH=src python examples/serve_retrieval.py
"""
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex, StaticPruner
from repro.data.synthetic import make_dataset
from repro.launch.serve import RetrievalServer

ds = make_dataset("ance", n_docs=20000, d=512, query_sets=("dl19",))
D = jnp.asarray(ds.docs)

pruner = StaticPruner(cutoff=0.5).fit(D)
index = DenseIndex.build(pruner.prune_index(D))
print(f"serving {index.n} docs at {index.dim} dims "
      f"({index.nbytes/2**20:.1f} MiB)")

server = RetrievalServer(index, pruner, k=10, max_batch=16)

lat: list[float] = []
lock = threading.Lock()


def client(worker: int, n: int):
    rng = np.random.default_rng(worker)
    for _ in range(n):
        q = ds.queries["dl19"][rng.integers(0, len(ds.queries["dl19"]))]
        t0 = time.time()
        scores, ids = server.query(q)
        with lock:
            lat.append(time.time() - t0)


threads = [threading.Thread(target=client, args=(w, 25)) for w in range(8)]
t0 = time.time()
for t in threads:
    t.start()
for t in threads:
    t.join()
wall = time.time() - t0
server.close()

ms = np.array(lat) * 1e3
print(f"{len(lat)} queries from 8 concurrent clients in {wall:.2f}s "
      f"({len(lat)/wall:.0f} qps)")
print(f"latency p50={np.percentile(ms, 50):.1f}ms "
      f"p95={np.percentile(ms, 95):.1f}ms p99={np.percentile(ms, 99):.1f}ms")
