"""Out-of-domain PCA transfer (paper RQ2): fit W_m on corpus A, prune corpus B.

  PYTHONPATH=src python examples/ood_transfer.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex, StaticPruner
from repro.core.metrics import evaluate_run, mean_metrics, wilcoxon_significant
from repro.data.synthetic import make_dataset, make_ood_corpus

# target corpus + its queries (think: BEIR TREC-COVID)
ds = make_dataset("tasb", n_docs=15000, d=768, query_sets=("covid",))
D = jnp.asarray(ds.docs)
Q = jnp.asarray(ds.queries["covid"])
qrels = ds.qrels["covid"]

# source corpus the transform is learned on (think: MS MARCO)
source = jnp.asarray(make_ood_corpus("tasb", n_docs=15000, d=768))


def ndcg(D_, Q_):
    _, ids = DenseIndex.build(D_).search(Q_, k=100)
    run = {i: np.asarray(ids)[i].tolist() for i in range(Q_.shape[0])}
    return evaluate_run(run, qrels)


base = ndcg(D, Q)
print(f"baseline          nDCG@10 = {base['nDCG@10'].mean():.4f}")

for c in (0.25, 0.5, 0.75):
    in_dom = StaticPruner(cutoff=c).fit(D)
    out_dom = StaticPruner(cutoff=c).fit(source)
    r_in = ndcg(in_dom.prune_index(D), in_dom.transform_queries(Q))
    r_out = ndcg(out_dom.prune_index(D), out_dom.transform_queries(Q))
    sig_in, _ = wilcoxon_significant(base["nDCG@10"], r_in["nDCG@10"])
    sig_out, _ = wilcoxon_significant(base["nDCG@10"], r_out["nDCG@10"])
    print(f"cutoff {int(c*100)}%:  in-domain {r_in['nDCG@10'].mean():.4f}"
          f"{'†' if sig_in else ' '}   out-of-domain "
          f"{r_out['nDCG@10'].mean():.4f}{'†' if sig_out else ' '}")
print("† = significant change vs baseline (paired Wilcoxon, α=0.05)")
