"""End-to-end driver: train a ~100M bi-encoder, encode a corpus, PCA-prune,
serve — the full production path of the paper's system.

Default invocation trains a width/depth-reduced encoder for a few hundred
steps so it finishes on this CPU container; pass ``--full`` for the real
BERT-base-scale (110M param) config (same code path — sized for a TPU pod).

  PYTHONPATH=src python examples/train_biencoder.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import DenseIndex, StaticPruner
from repro.core.metrics import evaluate_run, mean_metrics
from repro.data.tokens import Prefetcher, pair_batch
from repro.models.biencoder import BiEncoderConfig, contrastive_loss, encode, init_biencoder
from repro.optim import adamw_init, adamw_update, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=24)
    ap.add_argument("--full", action="store_true",
                    help="BERT-base scale (~110M params; pod-sized)")
    ap.add_argument("--ckpt-dir", default="/tmp/biencoder_ckpt")
    ap.add_argument("--cutoff", type=float, default=0.5)
    args = ap.parse_args()

    if args.full:
        cfg = BiEncoderConfig()  # 12L/768d/110M — the paper's encoder scale
    else:
        cfg = BiEncoderConfig(n_layers=4, d_model=128, n_heads=4, d_ff=512,
                              vocab=2048, embed_dim=128, max_len=64,
                              compute_dtype="float32", remat=False)
    print(f"[biencoder] {cfg.param_count()/1e6:.1f}M params")

    params = init_biencoder(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    lr_fn = warmup_cosine(3e-4, args.steps // 10, args.steps)

    @jax.jit
    def step(p, o, batch, t):
        loss, g = jax.value_and_grad(contrastive_loss)(p, batch, cfg)
        p, o = adamw_update(g, o, p, lr_fn(t))
        return p, o, loss

    mgr = CheckpointManager(args.ckpt_dir, keep_n=2)
    pf = Prefetcher(lambda t: pair_batch(0, t, batch=args.batch,
                                         seq_len=args.seq_len, vocab=cfg.vocab))
    t0 = time.time()
    try:
        for i in range(args.steps):
            _, hb = next(pf)
            batch = jax.tree.map(jnp.asarray, hb)
            params, opt, loss = step(params, opt, batch, i)
            if (i + 1) % 25 == 0:
                print(f"[train] step {i+1:4d} loss {float(loss):.4f} "
                      f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)")
            if (i + 1) % 100 == 0:
                mgr.save(i + 1, (params, opt))
    finally:
        pf.close()
        mgr.wait()

    # ---- encode corpus -----------------------------------------------------
    n_docs = 2000
    print(f"[encode] corpus of {n_docs} docs")
    docs, queries = [], []
    for i in range(0, n_docs, 64):
        b = pair_batch(7, i, batch=64, seq_len=args.seq_len, vocab=cfg.vocab)
        docs.append(b["d_tokens"])
        queries.append(b["q_tokens"])
    d_tok = np.concatenate(docs)[:n_docs]
    q_tok = np.concatenate(queries)[:64]
    ones_d = jnp.ones((n_docs, args.seq_len), jnp.int32)
    ones_q = jnp.ones((64, args.seq_len), jnp.int32)
    D = encode(params, jnp.asarray(d_tok), ones_d, cfg)
    Q = encode(params, jnp.asarray(q_tok), ones_q, cfg)
    qrels = {i: {i: 1} for i in range(64)}

    # ---- offline PCA prune + online serve -----------------------------------
    pruner = StaticPruner(cutoff=args.cutoff).fit(D)
    index = DenseIndex.build(pruner.prune_index(D))
    print(f"[prune] {D.shape[1]} -> {pruner.kept_dims} dims "
          f"({D.nbytes/2**20:.2f} -> {index.nbytes/2**20:.2f} MiB)")

    def mrr(ids):
        run = {i: np.asarray(ids)[i].tolist() for i in range(64)}
        return mean_metrics(evaluate_run(run, qrels, metrics=("MRR@10",)))["MRR@10"]

    _, ids_full = DenseIndex.build(D).search(Q, k=10)
    _, ids_pruned = index.search(pruner.transform_queries(Q), k=10)
    print(f"[serve] MRR@10 full={mrr(ids_full):.4f} "
          f"pruned={mrr(ids_pruned):.4f}")


if __name__ == "__main__":
    main()
