"""Quickstart: PCA static pruning in ~30 lines (paper §2, end to end).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex, StaticPruner
from repro.core.metrics import evaluate_run, mean_metrics
from repro.data.synthetic import make_dataset

# 1. a corpus of document embeddings (stand-in for an encoded MS MARCO)
ds = make_dataset("tasb", n_docs=10000, d=768, query_sets=("dl19",))
D = jnp.asarray(ds.docs)
Q = jnp.asarray(ds.queries["dl19"])

# 2. OFFLINE: fit PCA on the index, keep 50% of dims, build the pruned index
pruner = StaticPruner(cutoff=0.5).fit(D)          # D^T D = W Λ W^T
index = DenseIndex.build(pruner.prune_index(D))   # D̂ = D W_m
print(f"index: {D.shape[1]} -> {pruner.kept_dims} dims, "
      f"{D.nbytes/2**20:.1f} -> {index.nbytes/2**20:.1f} MiB")

# 3. ONLINE: transform queries (O(dm)) and search the pruned index (O(mn))
q_hat = pruner.transform_queries(Q)               # q̂ = W_m^T q
scores, ids = index.search(q_hat, k=10)

# 4. effectiveness vs the unpruned baseline
run = {i: np.asarray(ids)[i].tolist() for i in range(Q.shape[0])}
pruned = mean_metrics(evaluate_run(run, ds.qrels["dl19"]))

_, ids0 = DenseIndex.build(D).search(Q, k=10)
run0 = {i: np.asarray(ids0)[i].tolist() for i in range(Q.shape[0])}
base = mean_metrics(evaluate_run(run0, ds.qrels["dl19"]))

for m in ("nDCG@10", "MRR@10", "AP"):
    delta = 100 * (pruned[m] - base[m]) / max(base[m], 1e-9)
    print(f"{m:8s} baseline {base[m]:.4f} | 50%-pruned {pruned[m]:.4f} "
          f"({delta:+.1f}%)")
